"""Fault-injection sweeps: fail-slow / crash / media-error schedules vs the
host-side defenses (core/faults.py), each with self-checking acceptance
booleans:

* ``fail_slow`` — read-only foreground on RAID-5 with one persistently
  slow member (service times x6). Undefended, every submission stream
  eventually head-of-line blocks behind the slow member's full queue and
  the healthy peers starve (~11% utilization while the sick member pins
  at ~75%). Defended (hedged reads + the peer-relative detector with
  quarantine), late reads speculatively reconstruct from siblings and the
  suspect's admission is capped + reads steered away. Gates
  (seed-averaged): defended read p99 DOWN and the starved *healthy*
  members' min utilization UP vs undefended (the array-wide ``util_min``
  is the quarantined member itself, by design ~0 once reads steer
  around it); hedges fired and the slow member was quarantined.
* ``crash_rebuild`` — a member dies mid-run: its group plans degraded from
  the crash on, the rebuild tenant spawns at crash time, and the group
  heals when the spare is rebuilt. Gates: rebuild completes in-run on
  every seed (``rebuild_completed_at >= 0``), the redundancy gap
  ``data_at_risk_s`` is recorded, and the foreground p99 stays within
  ``CRASH_P99_BOUND`` x the fault-free baseline.
* ``retry_bound`` — uniform media errors under bounded host retries:
  retry chains never exceed ``max_retries`` re-issues, every retry is
  accounted to an injected error, and the whole faulted run is
  bit-deterministic (two runs at one seed produce identical results).

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.faults_sweep           # 18 SSDs
    PYTHONPATH=src python -m benchmarks.faults_sweep --smoke   # 6 SSDs, CI

Writes ``BENCH_faults.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.faults import Crash, FailSlow, FaultPolicy, MediaError, \
    RetryPolicy
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.raid import Raid5Layout

from .common import SSD, save

ROOT = Path(__file__).resolve().parent.parent

SLOW_FACTOR = 6.0
# foreground tail budget while degraded + rebuilding (x fault-free p99)
CRASH_P99_BOUND = 3.0


def _row(r, sick=None):
    out = {
        "iops": float(r.iops),
        "p99_ms": 1e3 * r.p99_latency,
        "p95_ms": 1e3 * r.p95_latency,
        "mean_ms": 1e3 * r.mean_latency,
        "util_min": float(r.util_min),
        "util_spread": float(r.util_spread),
        "degraded_reads": int(r.degraded_reads),
        "rebuild_rows": int(r.rebuild_rows),
        "events": int(r.events),
    }
    if sick is not None:
        # min utilization over the members that are NOT the injected
        # fail-slow device: the starvation the defense is meant to lift
        out["util_healthy_min"] = float(min(
            u for i, u in enumerate(r.util) if i != sick))
    if r.faults is not None:
        out["faults"] = dict(r.faults)
    return out


def _mean_rows(rows, keys):
    return {k: float(np.mean([row[k] for row in rows])) for k in keys}


def fail_slow_scenario(n_ssds, group, w_total, ops_per_ssd, seeds):
    """Read-only foreground on RAID-5, one member x6 slow from t=0:
    undefended vs hedged reads + detector quarantine. ``quarantine_qd=16``
    (half the host qd) rather than the aggressive default of 2: the cap
    must bound the suspect's backlog without head-of-line blocking the
    submission streams that still target it before steering kicks in."""
    wl = Workload(read_frac=1.0, w_total=w_total, qd_per_ssd=32,
                  n_streams=n_ssds)
    layout = Raid5Layout(group=group)
    slow = FailSlow(device=0, onset=0.0, slow_factor=SLOW_FACTOR)
    policies = {
        "no_defense": FaultPolicy(events=(slow,)),
        "defended": FaultPolicy(events=(slow,), hedge_after=1.5e-3,
                                detect=True, detect_min_samples=32,
                                detect_every=32, quarantine_qd=16),
    }
    out = {"config": {"n_ssds": n_ssds, "group": group, "w_total": w_total,
                      "ops_per_ssd": ops_per_ssd, "seeds": list(seeds),
                      "slow_factor": SLOW_FACTOR, "sick_device": 0}}
    for name, pol in policies.items():
        rows = []
        for seed in seeds:
            sim = ArraySim(n_ssds, SSD, 0.6, wl, seed=seed, layout=layout,
                           faults=pol, prefill_cache=True)
            rows.append(_row(sim.run(ops_per_ssd * n_ssds), sick=0))
        mean = _mean_rows(rows, ("iops", "p99_ms", "util_min",
                                 "util_healthy_min"))
        out[name] = {"seeds": rows, "mean": mean}
        f = rows[0]["faults"]
        print(f"  {name:11s} iops {mean['iops']:9,.0f}  "
              f"p99 {mean['p99_ms']:6.2f} ms  "
              f"peer util_min {mean['util_healthy_min']:.3f}  "
              f"hedges {f['hedged_reads']}/{f['hedge_wins']} won  "
              f"quarantines {f['quarantines']}")
    return out


def crash_rebuild_scenario(n_ssds, group, w_total, seeds):
    """Mixed workload on small-capacity members so the rebuild finishes
    in-run: baseline (faults=None) vs a mid-run member crash."""
    wl = Workload(read_frac=0.5, w_total=w_total, qd_per_ssd=32,
                  n_streams=n_ssds)
    layout = Raid5Layout(group=group)
    ssd = SSDParams(capacity_pages=2048)
    ops = 5000 * n_ssds
    crash = FaultPolicy(events=(Crash(device=1, at_time=0.05),))
    out = {"config": {"n_ssds": n_ssds, "group": group, "w_total": w_total,
                      "ops": ops, "seeds": list(seeds),
                      "capacity_pages": 2048, "crash_at": 0.05}}
    for name, pol in (("baseline", None), ("crash", crash)):
        rows = []
        for seed in seeds:
            sim = ArraySim(n_ssds, ssd, 0.5, wl, seed=seed, layout=layout,
                           faults=pol, prefill_cache=True)
            rows.append(_row(sim.run(ops)))
        mean = _mean_rows(rows, ("iops", "p99_ms", "mean_ms"))
        out[name] = {"seeds": rows, "mean": mean}
        if name == "crash":
            f = rows[0]["faults"]
            print(f"  {name:9s} iops {mean['iops']:9,.0f}  "
                  f"p99 {mean['p99_ms']:5.2f} ms  "
                  f"rebuilt @ {f['rebuild_completed_at']:.3f} s  "
                  f"at-risk {f['data_at_risk_s']:.3f} s  "
                  f"rows {rows[0]['rebuild_rows']}")
        else:
            print(f"  {name:9s} iops {mean['iops']:9,.0f}  "
                  f"p99 {mean['p99_ms']:5.2f} ms")
    return out


def retry_bound_scenario(n_ssds, w_total, ops_per_ssd, seeds):
    """JBOD + uniform media errors under bounded retries; one seed is run
    twice to pin bit-determinism of the faulted path."""
    wl = Workload(read_frac=0.7, w_total=w_total, qd_per_ssd=32,
                  n_streams=n_ssds)
    retry = RetryPolicy(max_retries=3, backoff=100e-6, backoff_mult=2.0)
    # deliberately absurd BER: the point is to exercise multi-step retry
    # chains (p(chain >= 2) = ber^2) and pin the bound, not realism
    pol = FaultPolicy(events=(MediaError(read_ber=0.05),), retry=retry)
    out = {"config": {"n_ssds": n_ssds, "w_total": w_total,
                      "ops_per_ssd": ops_per_ssd, "seeds": list(seeds),
                      "read_ber": 0.05, "max_retries": retry.max_retries}}
    rows = []
    for seed in seeds:
        sim = ArraySim(n_ssds, SSD, 0.6, wl, seed=seed, faults=pol,
                       prefill_cache=True)
        rows.append(_row(sim.run(ops_per_ssd * n_ssds)))
    out["seeds"] = rows
    twin = _row(ArraySim(n_ssds, SSD, 0.6, wl, seed=seeds[0], faults=pol,
                         prefill_cache=True).run(ops_per_ssd * n_ssds))
    out["deterministic"] = twin == rows[0]
    f = rows[0]["faults"]
    print(f"  media errors {f['media_errors']}, retries {f['retries']}, "
          f"deepest chain {f['max_attempts']} "
          f"(bound {retry.max_retries + 1}), "
          f"deterministic={out['deterministic']}")
    return out, retry.max_retries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small array (< 1 min), for CI / tests")
    ap.add_argument("--n-ssds", type=int, default=None)
    ap.add_argument("--group", type=int, default=None)
    ap.add_argument("--ops-per-ssd", type=int, default=None)
    ap.add_argument("--seeds", type=int, nargs="+", default=None)
    ap.add_argument("--out", default=str(ROOT / "BENCH_faults.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        n_ssds = args.n_ssds or 6
        group = args.group or 6
        ops = args.ops_per_ssd or 300
        seeds = tuple(args.seeds or (0, 1))
    else:
        n_ssds = args.n_ssds or 18
        group = args.group or 6
        ops = args.ops_per_ssd or 600
        seeds = tuple(args.seeds or (0, 1, 2))
    # moderate host window: deep enough to keep the array busy, shallow
    # enough that a slow member's backlog head-of-line blocks the streams —
    # the regime hedging and quarantine are for
    w_total = (128 * n_ssds) // 18

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_ssds": n_ssds,
        "group": group,
        "ops_per_ssd": ops,
        "seeds": list(seeds),
        "w_total": w_total,
    }
    print(f"fail-slow defense ({n_ssds} SSDs RAID-5 group {group}, "
          f"read-only, W={w_total}):")
    result["fail_slow"] = fail_slow_scenario(n_ssds, group, w_total, ops,
                                             seeds)
    print("mid-run crash -> rebuild (small members, RAID-5):")
    result["crash_rebuild"] = crash_rebuild_scenario(n_ssds, group, w_total,
                                                     seeds)
    print("media-error retry bound (JBOD):")
    result["retry_bound"], max_retries = retry_bound_scenario(
        n_ssds, w_total, ops, seeds)
    result["wall_s"] = time.perf_counter() - t0

    fs = result["fail_slow"]
    cr = result["crash_rebuild"]
    rb = result["retry_bound"]
    checks = {
        # the tentpole claim: hedged reads + quarantine pull the slow
        # member off the read path, cutting the tail and un-starving peers
        "defense_cuts_p99":
            fs["defended"]["mean"]["p99_ms"]
            < 0.8 * fs["no_defense"]["mean"]["p99_ms"],
        "defense_raises_peer_util_min":
            fs["defended"]["mean"]["util_healthy_min"]
            > fs["no_defense"]["mean"]["util_healthy_min"],
        "defense_hedges_fired": all(
            row["faults"]["hedged_reads"] > 0
            for row in fs["defended"]["seeds"]),
        "defense_quarantined_slow_member": all(
            row["faults"]["quarantines"] >= 1
            for row in fs["defended"]["seeds"]),
        # crash path: the rebuild tenant finishes while foreground load runs
        "rebuild_completes_every_seed": all(
            row["faults"]["rebuild_completed_at"] >= 0.0
            and row["faults"]["data_at_risk_s"] > 0.0
            for row in cr["crash"]["seeds"]),
        "crash_p99_bounded":
            cr["crash"]["mean"]["p99_ms"]
            < CRASH_P99_BOUND * cr["baseline"]["mean"]["p99_ms"],
        # retries: bounded, accounted, deterministic
        "retries_bounded": all(
            row["faults"]["max_attempts"] <= max_retries + 1
            and row["faults"]["retries"] <= row["faults"]["media_errors"]
            and row["faults"]["media_errors"] > 0
            for row in rb["seeds"]),
        "faulted_run_deterministic": rb["deterministic"],
    }
    result["checks"] = checks
    ok = all(checks.values())
    result["all_checks_pass"] = ok

    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_faults", result)
    print(f"faults sweep done in {result['wall_s']:.1f}s; checks: "
          + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                      for k, v in checks.items()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

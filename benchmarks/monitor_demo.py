"""Health-monitor demo + self-check: online alerting on the GC pathology.

Five scenarios, each with self-checking acceptance booleans:

* ``healthy`` — balanced mid-occupancy array (and a SAFS run): the rules
  stay SILENT. Zero alerts is the monitor's baseline claim; a monitor that
  pages on a healthy array is worse than none. The array runs use the
  default spec; the SAFS run uses the SAFS-calibrated ``SAFS_SPEC``
  (write-behind flushing makes deep per-device queues and short-window
  busy skew normal operation there).
* ``storm`` — write-heavy GC-heavy occupancy, reactive vs
  ``StaggeredGc(max_concurrent=1)``: the ``gc_storm`` rule fires on every
  reactive seed (all devices collecting at once — the paper's pathology)
  and never under the staggered lease. The telemetry of PR 8 made the storm
  *visible* post-hoc; the monitor raises it while the run is in flight.
* ``failslow`` — defended fail-slow scenario: a responsive monitor spec
  (``util_skew_window=8`` ticks) raises a ``util_skew`` alert with a
  ``fault:fail_slow`` root cause AT OR BEFORE the PR 7 detector's
  quarantine action. The detector judges over a conservative sweep cadence
  (``detect_every=1024`` service starts) because quarantine caps the
  member's admission — a drastic step — while the passive alert can afford
  to be trigger-happy: the operator hears about the sick device no later
  than the array acts on it.
* ``identity`` — monitoring ON must reproduce the monitor=None run
  byte-for-byte: the monitor piggybacks on the telemetry tick grid and
  schedules nothing, so it is a pure observer (same invariant as PR 8's
  telemetry).
* ``overhead`` — normalized events/sec with monitoring on must stay within
  10% of the unmonitored run (best-of-3 each).

Also writes the ``failslow`` run's alert stream as JSON-lines
(``BENCH_monitor_alerts.jsonl``, repo root — one alert per line with rule,
device, tenant, value, threshold, and root cause) and a Chrome trace
(``BENCH_monitor_trace.json``) with the alerts merged as Perfetto instant
events on the "alerts" track — open at https://ui.perfetto.dev.

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.monitor_demo           # full
    PYTHONPATH=src python -m benchmarks.monitor_demo --smoke   # CI

Writes ``BENCH_monitor.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

from repro.core.faults import FailSlow, FaultPolicy
from repro.core.gc_coord import ReactiveGc, StaggeredGc
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.monitor import RULES, MonitorSpec
from repro.core.safs_sim import SAFSSim, SAFSWorkload
from repro.core.telemetry import TelemetrySpec

from .common import save

ROOT = Path(__file__).resolve().parent.parent

SSD = SSDParams(capacity_pages=8192)

DEFAULT = MonitorSpec()
# failslow scenario: an 8-tick (8 ms) skew window so the alert latency is
# window-limited at ~one hundredth of the fail-slow duration
RESPONSIVE = MonitorSpec(util_skew_window=8)
# SAFS calibration: the write-behind flusher legitimately parks large dirty
# batches in the device queues (backlog threshold raised accordingly) and
# drains them one device at a time, so short-window busy skew across
# devices is normal operation, not a fault signature
SAFS_SPEC = MonitorSpec(rules=tuple(r for r in RULES if r != "util_skew"),
                        backlog_frac=8.0)

FAILSLOW_ONSET = 0.05
FAILSLOW_DEV = 1


def _wl(n_ssds):
    return Workload(w_total=32 * n_ssds, qd_per_ssd=32, n_streams=n_ssds)


def healthy_scenario(n_ssds, ops, seeds):
    """Balanced arrays at mid occupancy: the default rule set is silent."""
    rows = []
    for seed in seeds:
        r = ArraySim(n_ssds, SSD, 0.5, _wl(n_ssds), seed=seed,
                     monitor=DEFAULT).run(ops)
        rows.append({"kind": "array", "seed": seed,
                     "alerts": r.monitor.n_alerts,
                     "counts": dict(r.monitor.counts)})
    sr = SAFSSim(n_ssds, SSD, 0.6, SAFSWorkload(concurrency=16 * n_ssds),
                 seed=seeds[0], monitor=SAFS_SPEC).run(ops)
    rows.append({"kind": "safs", "seed": seeds[0],
                 "alerts": sr.monitor.n_alerts,
                 "counts": dict(sr.monitor.counts)})
    total = sum(row["alerts"] for row in rows)
    print(f"  {len(rows)} healthy runs, {total} alerts total")
    return {"config": {"n_ssds": n_ssds, "occupancy": 0.5, "ops": ops,
                       "seeds": list(seeds)},
            "runs": rows, "total_alerts": total}


def storm_scenario(n_ssds, occupancy, ops, seeds):
    """gc_storm fires under the reactive trigger, vanishes under the
    staggered lease."""
    out = {"config": {"n_ssds": n_ssds, "occupancy": occupancy, "ops": ops,
                      "seeds": list(seeds)}}
    for name, gc in (("reactive", ReactiveGc()),
                     ("staggered", StaggeredGc(max_concurrent=1))):
        rows = []
        for seed in seeds:
            r = ArraySim(n_ssds, SSD, occupancy, _wl(n_ssds), seed=seed,
                         gc=gc, monitor=DEFAULT).run(ops)
            storms = r.monitor.counts.get("gc_storm", 0)
            causes = sorted({a[7] for a in r.monitor.alerts
                             if a[2] == "gc_storm"})
            rows.append({"seed": seed, "gc_storm_alerts": storms,
                         "causes": causes,
                         "alerts": r.monitor.n_alerts,
                         "counts": dict(r.monitor.counts)})
        out[name] = rows
        mean = sum(row["gc_storm_alerts"] for row in rows) / len(rows)
        print(f"  {name:10s} gc_storm alerts/seed {mean:5.1f}")
    return out


def failslow_scenario(n_ssds, ops, seeds):
    """Defended fail-slow: the monitor's util_skew alert lands at or before
    the detector's quarantine, with a fault root-cause annotation."""
    rows = []
    for seed in seeds:
        fp = FaultPolicy(events=(FailSlow(device=FAILSLOW_DEV,
                                          onset=FAILSLOW_ONSET, duration=5.0,
                                          slow_factor=4.0),),
                         detect=True, detect_every=1024)
        sim = ArraySim(n_ssds, SSD, 0.5, _wl(n_ssds), seed=seed, faults=fp,
                       telemetry=TelemetrySpec(), monitor=RESPONSIVE)
        # no warmup: the onset and the race it times must fall inside the
        # measure window (warmup alerts are suppressed by design)
        r = sim.run(ops, 0)
        f = r.faults
        q_time = FAILSLOW_ONSET + f["detect_latency_s"] \
            if f["detect_latency_s"] >= 0 else None
        dev_alerts = [a for a in r.monitor.alerts
                      if a[0] >= FAILSLOW_ONSET
                      and (a[3] == FAILSLOW_DEV
                           or f"dev{FAILSLOW_DEV}" in a[7])]
        first = dev_alerts[0] if dev_alerts else None
        rows.append({
            "seed": seed,
            "onset_s": FAILSLOW_ONSET,
            "quarantine_s": q_time,
            "first_alert_s": first[0] if first else None,
            "first_alert_rule": first[2] if first else None,
            "first_alert_cause": first[7] if first else None,
            "alert_before_quarantine": bool(
                first is not None and q_time is not None
                and first[0] <= q_time),
            "cause_is_fault": bool(
                first is not None and first[7].startswith("fault:")),
            "quarantines": f["quarantines"],
            "counts": dict(r.monitor.counts),
        })
        print(f"  seed {seed}: alert {rows[-1]['first_alert_s']} "
              f"({rows[-1]['first_alert_cause']}) vs quarantine "
              f"{q_time and round(q_time, 4)} -> "
              f"{'OK' if rows[-1]['alert_before_quarantine'] else 'FAIL'}")
    return {"config": {"n_ssds": n_ssds, "ops": ops, "seeds": list(seeds),
                       "onset": FAILSLOW_ONSET, "slow_factor": 4.0,
                       "detect_every": 1024,
                       "util_skew_window": RESPONSIVE.util_skew_window},
            "runs": rows}


def identity_scenario(n_ssds, ops):
    """Monitoring ON is a pure observer: byte-identical to monitor=None."""
    wl = _wl(n_ssds)
    off = ArraySim(n_ssds, SSD, 0.6, wl, seed=42).run(ops)
    on = ArraySim(n_ssds, SSD, 0.6, wl, seed=42, monitor=DEFAULT).run(ops)
    out = {
        "iops_off": off.iops,
        "iops_on": on.iops,
        "p99_off": off.p99_latency,
        "p99_on": on.p99_latency,
        "events_off": off.events,
        "events_on": on.events,
        "alerts_on": on.monitor.n_alerts,
        "matches_off": bool(on.iops == off.iops
                            and on.events == off.events
                            and on.p99_latency == off.p99_latency),
    }
    print(f"  monitor-on iops {on.iops:,.2f} (off {off.iops:,.2f})  "
          f"{'OK' if out['matches_off'] else 'FAIL'}")
    return out


def _rate(monitor, ops):
    r = ArraySim(3, SSD, 0.6, _wl(3), seed=42, monitor=monitor).run(ops)
    return r.events / r.wall_s, r.events


def overhead_scenario(ops, repeats):
    """<10% normalized events/sec overhead with every rule on (gated).
    Off/on runs are interleaved and compared best-of-N (same deterministic
    event stream every run, so events/sec is directly comparable and
    best-of filters scheduler/thermal drift)."""
    rate_off = rate_on = 0.0
    ev_off = ev_on = 0
    for _ in range(repeats):
        r, ev_off = _rate(None, ops)
        rate_off = max(rate_off, r)
        r, ev_on = _rate(DEFAULT, ops)
        rate_on = max(rate_on, r)
    out = {
        "ops": ops,
        "repeats": repeats,
        "events": ev_off,
        "events_match": bool(ev_off == ev_on),
        "events_per_s_off": rate_off,
        "events_per_s_monitor": rate_on,
        "monitor_overhead_frac": rate_off / rate_on - 1.0,
    }
    print(f"  events/s: off {rate_off:,.0f}  monitor {rate_on:,.0f} "
          f"({100 * out['monitor_overhead_frac']:+.1f}%)")
    return out


def write_artifacts(n_ssds, ops, seed, jsonl_path, trace_path):
    """Alert JSON-lines + Perfetto trace (alerts as instant events on the
    "alerts" track) from one defended fail-slow run."""
    fp = FaultPolicy(events=(FailSlow(device=FAILSLOW_DEV,
                                      onset=FAILSLOW_ONSET, duration=5.0,
                                      slow_factor=4.0),),
                     detect=True, detect_every=1024)
    sim = ArraySim(n_ssds, SSD, 0.5, _wl(n_ssds), seed=seed, faults=fp,
                   telemetry=TelemetrySpec(spans=True), monitor=RESPONSIVE)
    r = sim.run(ops, 0)
    n_alerts = r.monitor.to_jsonl(jsonl_path)
    n_events = r.telemetry.export_trace(trace_path, monitor=r.monitor)
    print(f"  wrote {n_alerts} alerts -> {jsonl_path}")
    print(f"  wrote {n_events} trace events (alerts merged) -> {trace_path}")
    return {"alert_log": str(jsonl_path), "alerts": n_alerts,
            "trace": str(trace_path), "trace_events": n_events}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config for CI (fewer ops/seeds)")
    ap.add_argument("--ops", type=int, default=None)
    ap.add_argument("--seeds", type=int, nargs="+", default=None)
    ap.add_argument("--out", default=str(ROOT / "BENCH_monitor.json"))
    ap.add_argument("--alerts-out",
                    default=str(ROOT / "BENCH_monitor_alerts.jsonl"))
    ap.add_argument("--trace-out",
                    default=str(ROOT / "BENCH_monitor_trace.json"))
    args = ap.parse_args(argv)

    n_ssds = 3
    ops = args.ops or (6000 if args.smoke else 12000)
    seeds = tuple(args.seeds) if args.seeds else \
        ((0, 1) if args.smoke else (0, 1, 2))

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_ssds": n_ssds,
        "ops": ops,
        "seeds": list(seeds),
        "rules": list(DEFAULT.rules),
    }
    print(f"healthy baseline ({n_ssds} SSDs, occupancy 0.5 + SAFS):")
    result["healthy"] = healthy_scenario(n_ssds, ops, seeds)
    print("gc storm (occupancy 0.7, write-heavy):")
    result["storm"] = storm_scenario(n_ssds, 0.7, ops, seeds)
    print("defended fail-slow (alert vs quarantine race):")
    # fixed op count: the race window is in sim seconds, not ops
    result["failslow"] = failslow_scenario(n_ssds, 12000, seeds)
    print("monitor identity:")
    result["identity"] = identity_scenario(n_ssds, ops)
    # fixed size even under --smoke: the 10% gate needs runs long enough
    # that best-of-3 filters scheduler noise
    print("monitor overhead (best of 3):")
    result["overhead"] = overhead_scenario(24000, 3)
    print("alert artifacts:")
    result["artifacts"] = write_artifacts(
        n_ssds, 12000, seeds[0], args.alerts_out, args.trace_out)
    result["wall_s"] = time.perf_counter() - t0

    storm = result["storm"]
    fsl = result["failslow"]["runs"]
    checks = {
        # a monitor that pages on a healthy array is worse than none
        "healthy_zero_alerts": result["healthy"]["total_alerts"] == 0,
        # the paper's pathology raised ONLINE: every reactive seed storms...
        "storm_fires_reactive":
            all(row["gc_storm_alerts"] > 0 for row in storm["reactive"]),
        # ...and the staggered lease silences the rule entirely
        "storm_vanishes_staggered":
            all(row["gc_storm_alerts"] == 0 for row in storm["staggered"]),
        # the operator hears about the sick device no later than the array
        # quarantines it, with the fault named in the root cause
        "failslow_alert_before_quarantine":
            all(row["alert_before_quarantine"] and row["cause_is_fault"]
                for row in fsl),
        # pure-observer invariant
        "monitor_identity": result["identity"]["matches_off"],
        # rules ride the telemetry tick grid: same event count, <10% cost
        "overhead_under_10pct":
            result["overhead"]["events_match"]
            and result["overhead"]["monitor_overhead_frac"] < 0.10,
    }
    result["checks"] = checks
    ok = all(checks.values())
    result["all_checks_pass"] = ok

    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_monitor", result)
    print(f"monitor demo done in {result['wall_s']:.1f}s; checks: "
          + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                      for k, v in checks.items()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Roofline aggregation: reads experiments/dryrun/*.json into §Roofline tables.

Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --all
then:
  PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCH_IDS, cells

# prefer the optimized-defaults sweep; fall back to the baseline sweep
DRYRUN_DIRS = [Path("experiments/dryrun_opt"), Path("experiments/dryrun")]


def load(mesh: str = "single", dirs=None) -> list[dict]:
    rows = []
    for arch in ARCH_IDS:
        for shp in cells(arch):
            for d in (dirs or DRYRUN_DIRS):
                p = d / f"{arch}_{shp}_{mesh}.json"
                if p.exists():
                    rows.append(json.loads(p.read_text()))
                    break
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_ms':>10s} {'memory_ms':>10s} "
           f"{'coll_ms':>9s} {'bound':>10s} {'useful':>7s} {'AG_GB':>7s} "
           f"{'AR_GB':>7s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        rf = r["roofline"]
        cb = r["collectives"]["bytes"]
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{rf['compute_s'] * 1e3:10.2f} {rf['memory_s'] * 1e3:10.2f} "
            f"{rf['collective_s'] * 1e3:9.2f} {rf['bottleneck']:>10s} "
            f"{(r['useful_flop_ratio'] or 0):7.3f} "
            f"{cb.get('all-gather', 0) / 1e9:7.2f} "
            f"{cb.get('all-reduce', 0) / 1e9:7.2f}")
    return "\n".join(out)


def summarize(rows: list[dict]) -> dict:
    worst = min((r for r in rows if r["mode"] == "train"),
                key=lambda r: r["useful_flop_ratio"] or 0, default=None)
    coll_bound = max(rows, key=lambda r: r["roofline"]["collective_s"] /
                     max(r["roofline"]["compute_s"], 1e-12))
    return {
        "n_cells": len(rows),
        "worst_useful_train": worst and (worst["arch"], worst["shape"],
                                         worst["useful_flop_ratio"]),
        "most_collective_bound": (coll_bound["arch"], coll_bound["shape"]),
        "bottleneck_histogram": {
            b: sum(1 for r in rows if r["roofline"]["bottleneck"] == b)
            for b in ("compute", "memory", "collective")},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(args.mesh)
    if not rows:
        print("no dry-run artifacts found; run repro.launch.dryrun --all first")
        return
    print(fmt_table(rows))
    print()
    print(json.dumps(summarize(rows), indent=1))


if __name__ == "__main__":
    main()

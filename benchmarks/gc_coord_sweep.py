"""GC-coordination sweeps: WHEN members collect, benchmarked against the
reactive per-device trigger (core/gc_coord.py vs the paper's default).

Three scenarios, each with self-checking acceptance booleans:

* ``staggered`` — write-heavy RAID-5 at a moderate host window: the
  group-scoped GC lease with a proactive early trigger
  (``StaggeredGc(scope="group", early_blocks=...)``) rotates members
  through short, shallow episodes so no two members of a stripe group
  pause together. Gates (seed-averaged): min-member utilization UP and
  ``stripe_stall_p99`` DOWN vs ``ReactiveGc``.
* ``idle`` — bursty write-heavy JBOD: ``IdleGc`` reclaims in the arrival
  lulls, off the critical path. Gates: most GC time is idle-attributed
  (``idle_gc_frac``) and p99 latency drops vs reactive (whose episodes
  land mid-burst).
* ``identity`` — ``gc=None`` and ``ReactiveGc`` must reproduce the pinned
  golden byte-for-byte (the coordination plumbing is accounting-only on
  the reactive path).

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.gc_coord_sweep           # 18 SSDs
    PYTHONPATH=src python -m benchmarks.gc_coord_sweep --smoke   # 6 SSDs, CI

Writes ``BENCH_gc_coord.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.gc_coord import IdleGc, ReactiveGc, StaggeredGc
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.raid import Raid5Layout

from .common import SSD, save

ROOT = Path(__file__).resolve().parent.parent

# the PR 2 golden (tests/test_golden_determinism.py::GOLDEN_ARRAY_UNIFORM):
# 3 SSDs, capacity 4096, occupancy 0.6, w_total=96/qd=32/3 streams, seed 42,
# run(6000). The identity scenario reproduces it with and without gc=.
GOLDEN_IOPS = 79653.14748115413
GOLDEN_P99 = 0.005141150210084031


def _row(r):
    return {
        "iops": float(r.iops),
        "p99_ms": 1e3 * r.p99_latency,
        "stall_p99_ms": 1e3 * r.stripe_stall_p99,
        "util_min": float(r.util_min),
        "util_spread": float(r.util_spread),
        "gc_overlap_frac": float(r.gc_overlap_frac),
        "stagger_wait_mean_ms": 1e3 * r.stagger_wait_mean,
        "stagger_wait_p99_ms": 1e3 * r.stagger_wait_p99,
        "gc_starts": int(r.gc_starts),
        "gc_forced": int(r.gc_forced),
        "idle_gc_frac": float(r.idle_gc_frac),
        "steered_reads": int(r.steered_reads),
        "gc_pause_frac": float(np.mean(r.gc_pause_frac)),
        "gc_wa": float(r.gc_wa),
        "events": int(r.events),
    }


def _mean_rows(rows, keys):
    return {k: float(np.mean([row[k] for row in rows])) for k in keys}


def staggered_scenario(n_ssds, group, w_total, ops_per_ssd, seeds):
    """Write-heavy RAID-5, moderate window: reactive vs group-lease
    staggering (proactive early rotation), with and without host steering."""
    wl = Workload(w_total=w_total, qd_per_ssd=32, n_streams=n_ssds)
    layout = Raid5Layout(group=group)
    policies = {
        "reactive": ReactiveGc(),
        "staggered": StaggeredGc(max_concurrent=1, scope="group",
                                 early_blocks=4),
        "staggered_steer": StaggeredGc(max_concurrent=1, scope="group",
                                       early_blocks=4, steer=True),
    }
    out = {"config": {"n_ssds": n_ssds, "group": group, "w_total": w_total,
                      "qd_per_ssd": 32, "ops_per_ssd": ops_per_ssd,
                      "seeds": list(seeds)}}
    for name, gc in policies.items():
        rows = []
        for seed in seeds:
            sim = ArraySim(n_ssds, SSD, 0.6, wl, seed=seed, layout=layout,
                           gc=gc, prefill_cache=True)
            rows.append(_row(sim.run(ops_per_ssd * n_ssds)))
        mean = _mean_rows(rows, ("iops", "stall_p99_ms", "util_min",
                                 "gc_overlap_frac", "p99_ms"))
        out[name] = {"seeds": rows, "mean": mean}
        print(f"  {name:16s} iops {mean['iops']:9,.0f}  "
              f"stall_p99 {mean['stall_p99_ms']:5.2f} ms  "
              f"util_min {mean['util_min']:.3f}  "
              f"overlap {mean['gc_overlap_frac']:.3f}")
    return out


def idle_scenario(n_ssds, w_total, ops_per_ssd, seeds):
    """Bursty write-heavy JBOD: reactive pauses land mid-burst; IdleGc
    reclaims block-at-a-time in the OFF windows instead."""
    wl = Workload(w_total=w_total, qd_per_ssd=32, n_streams=n_ssds,
                  scenario="bursty", burst_on=2e-3, burst_off=4e-3)
    out = {"config": {"n_ssds": n_ssds, "w_total": w_total,
                      "ops_per_ssd": ops_per_ssd, "seeds": list(seeds),
                      "burst_on_ms": 2.0, "burst_off_ms": 4.0}}
    for name, gc in (("reactive", ReactiveGc()),
                     ("idle", IdleGc(watermark=24))):
        rows = []
        for seed in seeds:
            sim = ArraySim(n_ssds, SSD, 0.6, wl, seed=seed, gc=gc,
                           prefill_cache=True)
            rows.append(_row(sim.run(ops_per_ssd * n_ssds)))
        mean = _mean_rows(rows, ("iops", "p99_ms", "idle_gc_frac",
                                 "gc_pause_frac"))
        out[name] = {"seeds": rows, "mean": mean}
        print(f"  {name:9s} iops {mean['iops']:9,.0f}  "
              f"p99 {mean['p99_ms']:5.2f} ms  "
              f"idle_gc_frac {mean['idle_gc_frac']:.3f}")
    return out


def identity_scenario():
    """gc=None and ReactiveGc must reproduce the pinned golden exactly."""
    out = {}
    for name, gc in (("none", None), ("reactive", ReactiveGc())):
        r = ArraySim(3, SSDParams(capacity_pages=4096), 0.6,
                     Workload(w_total=96, qd_per_ssd=32, n_streams=3),
                     seed=42, gc=gc).run(6000)
        out[name] = {"iops": float(r.iops), "p99_s": float(r.p99_latency)}
        print(f"  gc={name:8s} iops {r.iops:.6f} "
              f"(golden {GOLDEN_IOPS:.6f})")
    out["matches_golden"] = (
        out["none"]["iops"] == GOLDEN_IOPS == out["reactive"]["iops"]
        and out["none"]["p99_s"] == GOLDEN_P99 == out["reactive"]["p99_s"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small array (< 1 min), for CI / tests")
    ap.add_argument("--n-ssds", type=int, default=None)
    ap.add_argument("--group", type=int, default=None)
    ap.add_argument("--ops-per-ssd", type=int, default=None)
    ap.add_argument("--seeds", type=int, nargs="+", default=None)
    ap.add_argument("--out", default=str(ROOT / "BENCH_gc_coord.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        n_ssds = args.n_ssds or 6
        group = args.group or 6
        ops = args.ops_per_ssd or 300
        seeds = tuple(args.seeds or (0, 1))
    else:
        n_ssds = args.n_ssds or 18
        group = args.group or 6
        ops = args.ops_per_ssd or 600
        seeds = tuple(args.seeds or (0, 1, 2))
    # moderate host window (~7 outstanding per SSD): deep enough for active
    # GC, shallow enough that a paused member's backlog starves the rest —
    # the regime the coordination is for
    w_total = (128 * n_ssds) // 18

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_ssds": n_ssds,
        "group": group,
        "ops_per_ssd": ops,
        "seeds": list(seeds),
        "w_total": w_total,
    }
    print(f"staggered vs reactive ({n_ssds} SSDs RAID-5 group {group}, "
          f"write-heavy, W={w_total}):")
    result["staggered"] = staggered_scenario(n_ssds, group, w_total, ops,
                                             seeds)
    print("idle GC under bursty load (JBOD):")
    result["idle"] = idle_scenario(n_ssds, w_total, ops, seeds)
    print("reactive identity vs goldens:")
    result["identity"] = identity_scenario()
    result["wall_s"] = time.perf_counter() - t0

    st = result["staggered"]
    idl = result["idle"]
    checks = {
        # the tentpole claim: group-lease staggering with proactive early
        # rotation lifts the starved member and cuts the stripe-stall tail
        "staggered_raises_util_min":
            st["staggered"]["mean"]["util_min"]
            > st["reactive"]["mean"]["util_min"],
        "staggered_cuts_stall_p99":
            st["staggered"]["mean"]["stall_p99_ms"]
            < 0.9 * st["reactive"]["mean"]["stall_p99_ms"],
        # steering redirects reads around GC-busy members only when asked
        "steering_off_means_no_steered_reads": all(
            row["steered_reads"] == 0 for row in st["staggered"]["seeds"]),
        # idle GC moves collection out of the busy phase and off the tail
        "idle_gc_shifts_off_busy_phase":
            idl["idle"]["mean"]["idle_gc_frac"] > 0.5,
        "idle_gc_cuts_p99":
            idl["idle"]["mean"]["p99_ms"] < idl["reactive"]["mean"]["p99_ms"],
        # byte-identity of the reactive path
        "reactive_matches_golden": result["identity"]["matches_golden"],
    }
    result["checks"] = checks
    ok = all(checks.values())
    result["all_checks_pass"] = ok

    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_gc_coord", result)
    print(f"gc-coord sweep done in {result['wall_s']:.1f}s; checks: "
          + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                      for k, v in checks.items()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Diff two benchmark artifacts and gate on regressions.

Compares two ``BENCH_*.json`` artifacts (any of the self-checking demos)
or two ``run.py --json`` summaries, flattening each to dotted-path scalar
metrics, and prints a regression table of per-metric relative deltas.

Gated regressions (nonzero exit):

* a ``checks.*`` boolean (or ``all_checks_pass``) that was true in the
  baseline and is false in the candidate — a self-check that used to pass
  now fails;
* a ``sections[].status`` (run.py summaries; keyed by section name) that
  goes ``ok`` -> ``fail``;
* any ``--gate PATH[:PCT]`` numeric metric whose relative drop vs the
  baseline exceeds PCT percent (default 10; higher-is-better convention —
  prefix the path with ``-`` for lower-is-better metrics like latency).

New/removed paths and non-gated numeric drift are reported but never fail
the diff: artifacts legitimately grow fields across PRs, and raw rates
move with the host. Only the explicit gates above are load-bearing.

Usage:
    PYTHONPATH=src python -m benchmarks.compare BASELINE CANDIDATE \
        [--gate overhead.events_per_s_off:15] [--min-delta 1.0]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

__all__ = ["flatten", "diff", "main"]


def flatten(obj, prefix: str = "") -> dict:
    """Flatten nested JSON to ``{dotted.path: scalar}``. Lists of objects
    that carry a ``"key"`` or ``"seed"`` field index by it (stable across
    reorderings); other lists index by position."""
    out: dict = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            tag = str(i)
            if isinstance(v, dict):
                for field in ("key", "seed"):
                    if field in v and not isinstance(v[field], (dict, list)):
                        tag = str(v[field])
                        break
            out.update(flatten(v, f"{prefix}.{tag}" if prefix else tag))
    elif isinstance(obj, (bool, int, float, str)) or obj is None:
        out[prefix] = obj
    return out


def _is_check(path: str, value) -> bool:
    return isinstance(value, bool) and (
        ".checks." in path or path.endswith("all_checks_pass")
        or path.startswith("checks."))


def _is_status(path: str, value) -> bool:
    return path.endswith(".status") and value in ("ok", "fail")


def diff(base: dict, cand: dict, gates: list, min_delta: float):
    """Compare flattened metric maps; returns (rows, regressions) where
    rows are display tuples and regressions are failure strings."""
    rows, regressions = [], []
    gate_map = {}
    for g in gates:
        path, _, pct = g.partition(":")
        lower_better = path.startswith("-")
        gate_map[path.lstrip("-")] = (float(pct) if pct else 10.0,
                                      lower_better)
    for path in sorted(set(base) | set(cand)):
        b, c = base.get(path), cand.get(path)
        if path not in cand:
            rows.append((path, b, "(removed)", ""))
            continue
        if path not in base:
            rows.append((path, "(new)", c, ""))
            continue
        if _is_check(path, b) or _is_check(path, c):
            if b is True and c is not True:
                rows.append((path, b, c, "REGRESSION"))
                regressions.append(f"check {path}: true -> {c}")
            elif b != c:
                rows.append((path, b, c, "changed"))
            continue
        if _is_status(path, b) or _is_status(path, c):
            if b == "ok" and c != "ok":
                rows.append((path, b, c, "REGRESSION"))
                regressions.append(f"section {path}: ok -> {c}")
            elif b != c:
                rows.append((path, b, c, "changed"))
            continue
        if isinstance(b, (int, float)) and isinstance(c, (int, float)) \
                and not isinstance(b, bool) and not isinstance(c, bool):
            delta = (c - b) / abs(b) if b else (0.0 if c == b else
                                                float("inf"))
            gate = gate_map.get(path)
            if gate is not None:
                pct, lower_better = gate
                drop = delta if lower_better else -delta
                if drop * 100.0 > pct:
                    rows.append((path, b, c, f"{100 * delta:+.1f}% "
                                 f"REGRESSION (gate {pct:g}%)"))
                    regressions.append(
                        f"metric {path}: {b:g} -> {c:g} "
                        f"({100 * delta:+.1f}%, gate {pct:g}%)")
                    continue
            if abs(delta) * 100.0 >= min_delta:
                rows.append((path, b, c, f"{100 * delta:+.1f}%"))
            continue
        if b != c:
            rows.append((path, b, c, "changed"))
    return rows, regressions


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="baseline artifact (JSON)")
    ap.add_argument("candidate", help="candidate artifact (JSON)")
    ap.add_argument("--gate", action="append", default=[],
                    metavar="PATH[:PCT]",
                    help="numeric metric to gate: fail if it drops more "
                         "than PCT%% vs baseline (default 10; prefix the "
                         "path with '-' for lower-is-better metrics)")
    ap.add_argument("--min-delta", type=float, default=1.0,
                    help="hide numeric drift below this %% (default 1)")
    args = ap.parse_args(argv)

    base = flatten(json.loads(Path(args.baseline).read_text()))
    cand = flatten(json.loads(Path(args.candidate).read_text()))
    rows, regressions = diff(base, cand, args.gate, args.min_delta)

    print(f"comparing {args.baseline} (baseline) -> {args.candidate}")
    print(f"{len(base)} baseline metrics, {len(cand)} candidate metrics, "
          f"{len(rows)} differences shown (|delta| >= "
          f"{args.min_delta:g}%)\n")
    if rows:
        w = max(len(r[0]) for r in rows)
        for path, b, c, note in rows:
            print(f"  {path:<{w}}  {_fmt(b):>14} -> {_fmt(c):<14} {note}")
    else:
        print("  no differences")
    if regressions:
        print(f"\n{len(regressions)} gated regression(s):")
        for r in regressions:
            print(f"  FAIL {r}")
        return 1
    print("\nno gated regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Engine performance benchmark: events/sec of the DES hot path.

Tracks the perf trajectory of the simulator from PR 2 on. Three tiers:

* ``engine_micro`` — raw ``EventLoop`` dispatch (payload-record events, no
  simulator on top): the ceiling of the event engine itself.
* ``qd_point`` / ``qd_sweep`` — the paper's 18-SSD queue-depth sweep
  (the acceptance configuration), single process, best-of-``repeats``.
* ``sharded_sweep`` — the same sweep through ``ShardedArraySim``:
  aggregate events/sec = total events / total wall clock across worker
  processes.

Because absolute events/sec depends on the host, every run also measures a
pure-Python ``calibrate()`` workload (function calls + heapq churn, the same
primitives the engine spends its time on) and reports
``norm = events_per_sec / calib_score``; the CI regression gate
(``--check``) compares the *normalized* number against the committed
baseline, so a slower CI machine does not trip it.

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.perf_bench           # full benchmark
    PYTHONPATH=src python -m benchmarks.perf_bench --smoke   # < 1 min CI tier
    PYTHONPATH=src python -m benchmarks.perf_bench --smoke \
        --check benchmarks/BENCH_engine_baseline.json

Writes ``BENCH_engine.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from heapq import heappop, heappush
from pathlib import Path

from repro.core.engine import EventLoop
from repro.core.gc_sim import ArraySim, Workload, clear_prefill_cache
from repro.core.sharded import ShardedArraySim

from .common import SSD, save

ROOT = Path(__file__).resolve().parent.parent

# >30% normalized events/sec regression vs the committed baseline fails CI
REGRESSION_TOLERANCE = 0.30


def calibrate(n: int = 200_000) -> float:
    """Machine-speed score (ops/sec) on the primitives the engine uses:
    Python function calls, tuple churn, and heapq push/pop."""

    def f(x):
        return x + 1

    heap: list = []
    t0 = time.perf_counter()
    for i in range(n):
        heappush(heap, (float(i & 1023), i, f(i)))
        if i & 1:
            heappop(heap)
    dt = time.perf_counter() - t0
    return n / dt


def engine_micro(n_events: int = 300_000, standing: int = 64) -> dict:
    """Raw EventLoop dispatch rate: a self-rescheduling payload handler plus
    ``standing`` self-rescheduling no-payload events (exercises slot reuse
    and the scheduler at a controlled pending-event population).

    ``standing`` sets the regime: 64 is the legacy shallow config (a tiny
    queue, where a C binary heap is near-unbeatable); 2048 matches the
    pending count of the paper's acceptance config (18 SSDs x qd 128), the
    regime the calendar queue is built for. The run stops at a precomputed
    sim-time horizon so the stop condition costs nothing per event."""
    loop = EventLoop()

    def tick(payload):
        loop.call(0.001, tick, payload)

    def noop():
        loop.call(0.0037, noop)

    for _ in range(standing):
        loop.call(0.0037, noop)
    loop.call(0.001, tick, ("payload",))
    horizon = n_events / (standing / 0.0037 + 1.0 / 0.001)
    loop.call_at(horizon, loop.stop)
    t0 = time.perf_counter()
    processed = loop.run()
    dt = time.perf_counter() - t0
    return {"events": processed, "standing": standing, "wall_s": dt,
            "events_per_sec": processed / dt}


def qd_point(n_ssds: int, qd: int, measure_ops: int, seed: int = 0,
             repeats: int = 2) -> dict:
    """One sweep point, single process. Construction uses the prefill
    snapshot cache (sweep points share params+seed); run wall time is the
    best of ``repeats`` (the DES is deterministic, so repeats only shed
    scheduler noise)."""
    best = None
    construct_s = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        sim = ArraySim(n_ssds, SSD, 0.6,
                       Workload(w_total=n_ssds * qd, qd_per_ssd=qd,
                                n_streams=n_ssds),
                       seed=seed, prefill_cache=True)
        tc = time.perf_counter() - t0
        construct_s = tc if construct_s is None else min(construct_s, tc)
        r = sim.run(measure_ops)
        if best is None or r.wall_s < best[0]:
            best = (r.wall_s, r)
    wall, r = best
    return {"qd": qd, "iops": r.iops, "events": r.events, "run_wall_s": wall,
            "construct_s": construct_s, "events_per_sec": r.events / wall,
            "p99_ms": 1e3 * r.p99_latency}


def qd_sweep(n_ssds: int = 18, qds=(1, 4, 32, 128), measure_ops: int = 30000,
             repeats: int = 2) -> dict:
    clear_prefill_cache()
    t0 = time.perf_counter()
    points = [qd_point(n_ssds, qd, measure_ops, repeats=repeats) for qd in qds]
    total_wall = time.perf_counter() - t0
    ev = sum(p["events"] for p in points)
    run_wall = sum(p["run_wall_s"] for p in points)
    return {
        "n_ssds": n_ssds, "measure_ops": measure_ops, "points": points,
        "events": ev, "run_wall_s": run_wall, "sweep_wall_s": total_wall,
        "events_per_sec": ev / run_wall,
        "iops_monotone": all(b["iops"] > a["iops"]
                             for a, b in zip(points, points[1:])),
    }


def sharded_sweep(n_ssds: int = 18, qds=(1, 4, 32, 128),
                  measure_ops: int = 30000, n_shards: int | None = None) -> dict:
    """The same sweep through ShardedArraySim.

    ``events_per_sec`` is the aggregate run-phase rate: per point, total
    events divided by the slowest shard's run wall (the parallel critical
    path; per-worker prefill caches make construction a one-off). A small
    warmup run first spins up the worker pool and populates those caches so
    the measured points aren't charged for process start-up."""
    warm = ShardedArraySim(
        n_ssds, SSD, 0.6,
        Workload(w_total=n_ssds * qds[0], qd_per_ssd=qds[0],
                 n_streams=n_ssds),
        seed=0, n_shards=n_shards)
    warm.run(max(measure_ops // 10, 50 * n_ssds))
    points = []
    ev = 0
    run_wall = 0.0
    total_wall = 0.0
    t0 = time.perf_counter()
    for qd in qds:
        sim = ShardedArraySim(
            n_ssds, SSD, 0.6,
            Workload(w_total=n_ssds * qd, qd_per_ssd=qd, n_streams=n_ssds),
            seed=0, n_shards=n_shards)
        r = sim.run(measure_ops)
        ev += r.events
        run_wall += r.wall_s            # max over shards = critical path
        total_wall += sim.last_wall_s
        points.append({"qd": qd, "iops": r.iops, "events": r.events,
                       "run_wall_s": r.wall_s, "wall_s": sim.last_wall_s,
                       "p99_ms": 1e3 * r.p99_latency})
    return {
        "n_ssds": n_ssds, "n_shards": len(warm.sizes),
        "points": points, "events": ev, "run_wall_s": run_wall,
        "wall_s": total_wall,
        "sweep_wall_s": time.perf_counter() - t0,
        "events_per_sec": ev / run_wall,
        "iops_monotone": all(b["iops"] > a["iops"]
                             for a, b in zip(points, points[1:])),
    }


def run_bench(smoke: bool = False) -> dict:
    calib = calibrate(100_000 if smoke else 200_000)
    n_micro = 100_000 if smoke else 300_000
    micro = engine_micro(n_micro)
    micro_deep = engine_micro(n_micro, standing=2048)
    if smoke:
        sweep = qd_sweep(n_ssds=4, qds=(4, 32), measure_ops=6000, repeats=2)
        sharded = sharded_sweep(n_ssds=8, qds=(4, 32), measure_ops=12000,
                                n_shards=2)
    else:
        sweep = qd_sweep()
        sharded = sharded_sweep()
    out = {
        "smoke": smoke,
        "cpu_count": os.cpu_count(),
        "calib_score": calib,
        "engine_micro": micro,
        "engine_micro_deep": micro_deep,
        "qd_sweep": sweep,
        "sharded_qd_sweep": sharded,
        # normalized metrics: machine-independent regression gates
        "norm_micro": micro["events_per_sec"] / calib,
        "norm_micro_deep": micro_deep["events_per_sec"] / calib,
        "norm_qd_sweep": sweep["events_per_sec"] / calib,
        "norm_sharded": sharded["events_per_sec"] / calib,
    }
    return out


# Gated metrics: single-process rates normalized by the single-threaded
# calibration score, so machine speed cancels. norm_sharded is reported but
# NOT gated — a multi-process aggregate over a single-threaded calibration
# tracks core count and scheduler contention, not engine regressions.
GATED_METRICS = ("norm_micro", "norm_micro_deep", "norm_qd_sweep")


def check_regression(result: dict, baseline_path: str) -> int:
    """Bidirectional perf gate.

    Downward: every gated metric must stay within ``REGRESSION_TOLERANCE``
    of its committed baseline. Upward: the baseline's ``require_at_least``
    block records the old *heap* engine's normalized rates (min of repeated
    runs minus headroom) — the calendar-queue engine must keep beating them,
    so the claimed events/sec win cannot silently evaporate in a later
    change while the ordinary 30%-of-own-baseline floor still passes."""
    base = json.loads(Path(baseline_path).read_text())
    failures = []
    for key in GATED_METRICS:
        have, want = result.get(key), base.get(key)
        if want is None:
            continue
        floor = want * (1.0 - REGRESSION_TOLERANCE)
        status = "OK" if have >= floor else "REGRESSION"
        print(f"  {key}: {have:.3f} vs baseline {want:.3f} "
              f"(floor {floor:.3f}) {status}")
        if have < floor:
            failures.append(key)
    for key, want in base.get("require_at_least", {}).items():
        have = result.get(key)
        if have is None:
            continue
        status = "OK" if have >= want else "LOST-SPEEDUP"
        print(f"  {key}: {have:.3f} vs required floor {want:.3f} "
              f"(heap-engine record) {status}")
        if have < want:
            failures.append(f"{key}>=heap")
    if failures:
        print(f"perf gate failed in: {', '.join(failures)}")
        return 1
    print("perf check passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small configs (< 1 min), for CI")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) on >30%% normalized regression vs "
                         "this baseline JSON")
    ap.add_argument("--out", default=str(ROOT / "BENCH_engine.json"))
    args = ap.parse_args(argv)

    result = run_bench(smoke=args.smoke)
    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_engine", result)

    m = result["engine_micro"]
    md = result["engine_micro_deep"]
    s = result["qd_sweep"]
    sh = result["sharded_qd_sweep"]
    print(f"engine micro : {m['events_per_sec']:,.0f} events/s "
          f"(deep: {md['events_per_sec']:,.0f} @ {md['standing']} standing)")
    print(f"qd sweep     : {s['events_per_sec']:,.0f} events/s "
          f"({s['n_ssds']} SSDs, run {s['run_wall_s']:.2f}s, "
          f"sweep {s['sweep_wall_s']:.2f}s, monotone={s['iops_monotone']})")
    print(f"sharded sweep: {sh['events_per_sec']:,.0f} events/s "
          f"({sh['n_shards']} shards, wall {sh['wall_s']:.2f}s)")
    print(f"calibration  : {result['calib_score']:,.0f} ops/s; normalized "
          f"micro {result['norm_micro']:.2f} / sweep "
          f"{result['norm_qd_sweep']:.3f} / sharded {result['norm_sharded']:.3f}")

    if args.check:
        return check_regression(result, args.check)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Production-scale SAFS sweeps via ``ShardedSAFSSim`` (100+ SSDs).

The paper's headline claims are about the SAFS page-cache system, but until
the sharded SAFS path existed only the raw array scaled past 18 SSDs. This
sweep runs the full SAFS stack (SA-cache + dirty-page flusher + dual queues)
at 18/64/128 SSDs under the pattern suite and records, per pattern:

* cache hit rate (recomputed from pooled raw counters),
* writeback volume (flusher writes + application-blocking demand writes,
  and the demand share of the total), and
* p99 application latency (exact over pooled raw samples).

Self-checks (any violation exits nonzero, making the committed
``BENCH_safs_scale.json`` self-checking):

* serial == sharded: ``parallel=False`` on the same shard decomposition is
  bit-identical to the process-pool run (spot-checked at the smallest size),
* locality ordering: skewed patterns (``zipf``, ``hot_cold``) beat
  ``random``'s hit rate at every size — the SA-cache must actually exploit
  skew,
* flusher effectiveness: with the flusher on, background flushes dominate
  writeback (demand share < 50%) for the random/skewed patterns — ``strided``
  is exempt: a full-coverage scan misses on every op, so demand evictions
  legitimately dominate there (that stress is what the pattern is for),
* accounting sanity: hit rates in [0, 1], p99 > 0, writeback volume and SSD
  page programs both positive.

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.safs_scale_sweep           # 18/64/128
    PYTHONPATH=src python -m benchmarks.safs_scale_sweep --smoke   # CI tier
    PYTHONPATH=src python -m benchmarks.safs_scale_sweep --sizes 18 --patterns zipf

Writes ``BENCH_safs_scale.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

from repro.core.safs_sim import SAFSWorkload
from repro.core.sharded import ShardedSAFSSim

from .common import SSD, save

ROOT = Path(__file__).resolve().parent.parent

# per-SSD closed-loop concurrency (the paper's async 32 x n_ssds config)
CONCURRENCY_PER_SSD = 32

# pattern vocabulary of the sweep: name -> SAFSWorkload kwargs
PATTERNS = {
    "random": dict(dist="uniform", scenario="random"),
    "zipf": dict(dist="zipf", scenario="random"),
    "hot_cold": dict(scenario="hot_cold"),
    "strided": dict(scenario="strided"),
}
# skewed patterns that must beat "random"'s hit rate
SKEWED = ("zipf", "hot_cold")
# patterns where background flushes must dominate writeback (scan patterns
# like "strided" miss on every op, so demand evictions dominate by design)
DEMAND_CHECKED = ("random", "zipf", "hot_cold")


def run_point(n_ssds: int, pattern: str, measure_ops: int, read_frac: float,
              n_shards: int, parallel: bool = True) -> dict:
    wl = SAFSWorkload(read_frac=read_frac,
                      concurrency=CONCURRENCY_PER_SSD * n_ssds,
                      **PATTERNS[pattern])
    sim = ShardedSAFSSim(n_ssds, SSD, 0.8, wl, seed=0, n_shards=n_shards,
                         parallel=parallel)
    r = sim.run(measure_ops)
    writeback = r.flush_writes + r.demand_writes
    return {
        "pattern": pattern, "n_ssds": n_ssds,
        "app_iops": float(r.app_iops),
        "hit_rate": float(r.hit_rate),
        "writeback_pages": int(writeback),
        "flush_writes": int(r.flush_writes),
        "demand_writes": int(r.demand_writes),
        "demand_share": r.demand_writes / max(writeback, 1),
        "ssd_page_writes": int(r.ssd_page_writes),
        "p99_ms": 1e3 * r.p99_latency,
        "events": int(r.events),
        "wall_s": sim.last_wall_s,
    }


def sweep_size(n_ssds: int, patterns, ops_per_ssd: int, read_frac: float,
               n_shards: int) -> dict:
    """Pattern sweep at one array size; measurement budget scales with the
    array so per-pattern statistics keep a comparable sample count."""
    measure_ops = ops_per_ssd * n_ssds
    out = {"n_ssds": n_ssds, "measure_ops": measure_ops, "patterns": {}}
    for pat in patterns:
        p = run_point(n_ssds, pat, measure_ops, read_frac, n_shards)
        out["patterns"][pat] = p
        print(f"  n={n_ssds} {pat:9s}: {p['app_iops']:,.0f} IOPS, "
              f"hit {p['hit_rate']:.3f}, wb {p['writeback_pages']} pages "
              f"(demand {100 * p['demand_share']:.0f}%), "
              f"p99 {p['p99_ms']:.2f} ms, {p['wall_s']:.1f}s")
    return out


def self_check(result: dict, patterns) -> list[str]:
    """Invariant checks over the finished sweep; returns violation strings."""
    bad = []
    for n, size in result["sizes"].items():
        pts = size["patterns"]
        for pat, p in pts.items():
            where = f"n={n} {pat}"
            if not (0.0 <= p["hit_rate"] <= 1.0):
                bad.append(f"{where}: hit_rate {p['hit_rate']} outside [0,1]")
            if p["p99_ms"] <= 0.0:
                bad.append(f"{where}: p99 {p['p99_ms']} not positive")
            if p["writeback_pages"] <= 0:
                bad.append(f"{where}: no writeback despite writes")
            if p["ssd_page_writes"] <= 0:
                bad.append(f"{where}: no SSD page programs despite writes")
            if pat in DEMAND_CHECKED and p["demand_share"] >= 0.5:
                bad.append(f"{where}: demand writebacks dominate "
                           f"({100 * p['demand_share']:.0f}%) — flusher "
                           "not keeping up")
        if "random" in pts:
            base = pts["random"]["hit_rate"]
            for pat in SKEWED:
                if pat in pts and pts[pat]["hit_rate"] <= base:
                    bad.append(f"n={n}: {pat} hit rate "
                               f"{pts[pat]['hit_rate']:.3f} does not beat "
                               f"random's {base:.3f}")
    if not result["serial_matches_sharded"]:
        bad.append("parallel=False and parallel=True runs differ on the "
                   "same shard decomposition (merge path broken)")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: fewer patterns/ops, still reaches 128 SSDs")
    ap.add_argument("--sizes", type=int, nargs="+", default=None)
    ap.add_argument("--patterns", nargs="+", default=None,
                    choices=sorted(PATTERNS))
    ap.add_argument("--ops-per-ssd", type=int, default=None)
    ap.add_argument("--read-frac", type=float, default=0.3)
    ap.add_argument("--shards", type=int, default=None,
                    help="worker shard count (default: pinned per tier, NOT "
                         "cpu_count — results are deterministic only for a "
                         "fixed (seed, n_shards), so the self-checks and "
                         "BENCH_safs_scale.json must not depend on the host)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_safs_scale.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        sizes = args.sizes or [18, 128]
        patterns = args.patterns or ["random", "zipf", "hot_cold"]
        ops = args.ops_per_ssd or 150
        n_shards = args.shards or 2
    else:
        sizes = args.sizes or [18, 64, 128]
        patterns = args.patterns or sorted(PATTERNS)
        ops = args.ops_per_ssd or 500
        n_shards = args.shards or 4

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_shards": n_shards,
        "ops_per_ssd": ops,
        "read_frac": args.read_frac,
        "concurrency_per_ssd": CONCURRENCY_PER_SSD,
        "sizes": {},
    }
    for n in sizes:
        print(f"n_ssds={n}:")
        result["sizes"][str(n)] = sweep_size(n, patterns, ops,
                                             args.read_frac, n_shards)

    # merge-path check: same decomposition, in-process vs worker pool
    n0, pat0 = sizes[0], patterns[0]
    a = run_point(n0, pat0, ops * n0, args.read_frac, n_shards, parallel=True)
    b = run_point(n0, pat0, ops * n0, args.read_frac, n_shards, parallel=False)
    result["serial_matches_sharded"] = all(
        a[k] == b[k] for k in a if k != "wall_s")
    result["wall_s"] = time.perf_counter() - t0

    violations = self_check(result, patterns)
    result["self_check_violations"] = violations
    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_safs_scale", result)

    biggest = result["sizes"][str(sizes[-1])]
    ev = sum(p["events"] for p in biggest["patterns"].values())
    wall = sum(p["wall_s"] for p in biggest["patterns"].values())
    print(f"safs scale sweep done in {result['wall_s']:.1f}s; largest array "
          f"{sizes[-1]} SSDs @ {ev / max(wall, 1e-9):,.0f} ev/s; "
          f"serial==sharded: {result['serial_matches_sharded']}")
    if violations:
        print("SELF-CHECK FAILED:")
        for v in violations:
            print(f"  - {v}")
        return 1
    print("self-check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Beyond-paper validation: the transplanted flusher in the PAGED-KV SERVING
engine. Measures preemption cost with/without background pre-cleaning —
the serving analogue of paper Fig 3/5 (blocking work off the critical path).
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models.transformer import init_params
from repro.serving import ServeEngine

from .common import save


def run(arch: str = "tinyllama-1.1b", n_requests: int = 8,
        max_new: int = 24, seed: int = 5) -> dict:
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    out = {}
    for use_flusher in (True, False):
        eng = ServeEngine(cfg, params, max_batch=4, page_size=8, num_sets=4,
                          set_size=3, use_flusher=use_flusher)
        rng = np.random.default_rng(seed)
        prompts = [[int(x) for x in rng.integers(1, 250, 16)]
                   for _ in range(n_requests)]
        rids = [eng.submit(p, max_new=max_new) for p in prompts]
        t0 = time.time()
        eng.run(2000)
        dt = time.time() - t0
        st = eng.stats()
        toks = sum(len(eng.result(r).out) for r in rids)
        st["tokens"] = toks
        st["wall_s"] = dt
        out["flusher_on" if use_flusher else "flusher_off"] = st
        eng.close()
    on, off = out["flusher_on"], out["flusher_off"]
    out["blocking_offload_reduction"] = off["blocking_offloads"] - \
        on["blocking_offloads"]
    save("serving_flusher", out)
    return out


def main():
    r = run()
    on, off = r["flusher_on"], r["flusher_off"]
    print(f"serving w/ flusher:   blocking_offloads={on['blocking_offloads']} "
          f"clean_evictions={on['clean_evictions']} "
          f"stale_discards={on['stale_discards']}")
    print(f"serving w/o flusher:  blocking_offloads={off['blocking_offloads']} "
          f"clean_evictions={off['clean_evictions']}")
    print(f"blocking offloads removed from the critical path: "
          f"{r['blocking_offload_reduction']}")


if __name__ == "__main__":
    main()

"""Per-tenant QoS acceptance sweeps: weighted fair shares, SLO protection
under active GC, and the multi-tenant scale path (core/qos.py).

The paper's headline experiment is a latency-sensitive reader sharing the
array with a random writer whose traffic drives unsynchronized GC; this
sweep quantifies what the QoS subsystem adds on top of the shared engine:

* ``weight_sweep`` — two greedy write tenants at saturation (window-bound:
  ``w_total < n * qd`` keeps host-queue parking out of the way, so the DRR
  sets admission shares). Achieved throughput shares must track the
  configured weights within 10% relative.
* ``slo_protection`` — the ISSUE scenario: a Zipf reader with a p99 SLO vs
  a random writer driving active GC. Run once with a telemetry-only policy
  (no SLO: the "without QoS" baseline — same per-tenant instrumentation, no
  enforcement) to measure the interference, then with the SLO set to 20% of
  the baseline p99 so the controller must throttle the writer. The
  protected reader's p99 must improve, and the writer must show throttle
  time and a reduced share.
* ``scale_tenants`` — 3 tenants (protected Zipf reader, weighted writer,
  rate-capped writer) on ``ShardedArraySim``: the parallel worker path must
  be bit-identical to the same shard decomposition run serially, per tenant.

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.qos_sweep            # 12 SSDs
    PYTHONPATH=src python -m benchmarks.qos_sweep --smoke    # 6 SSDs, CI

Writes ``BENCH_qos.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.gc_sim import ArraySim, Workload
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.sharded import ShardedArraySim

from .common import SSD, save

ROOT = Path(__file__).resolve().parent.parent


def _tenant_rows(r) -> dict:
    return {
        str(t): {
            "weight": st.weight,
            "ops": int(st.ops),
            "throughput": float(st.throughput),
            "share": float(st.share),
            "weight_share": float(st.weight_share),
            "p50_ms": 1e3 * st.p50_latency,
            "p95_ms": 1e3 * st.p95_latency,
            "p99_ms": 1e3 * st.p99_latency,
            "throttle_time_ms": 1e3 * st.throttle_time,
            "slo_p99_ms": None if st.slo_p99 is None else 1e3 * st.slo_p99,
            "rate_iops": st.rate_iops,
        }
        for t, st in sorted(r.tenant_stats.items())
    }


def weight_sweep(n_ssds, qd, ops_per_ssd, seed=0):
    """Two greedy write tenants; achieved shares must track DRR weights."""
    measure_ops = ops_per_ssd * n_ssds
    # window-bound saturation: qd_per_ssd >= w_total means a host queue can
    # never fill (no head-of-line parking, which would override the
    # scheduler during multi-ms GC pauses) — the DRR arbitrates EVERY
    # admission and shares are exactly the weights
    W = n_ssds * qd // 2
    wl = Workload(w_total=W, qd_per_ssd=W)
    out = {}
    for w0, w1 in ((1.0, 1.0), (2.0, 1.0), (4.0, 1.0)):
        pol = QosPolicy(tenants=(TenantSpec(0, weight=w0),
                                 TenantSpec(1, weight=w1)))
        r = ArraySim(n_ssds, SSD, 0.6, wl, seed=seed, qos=pol,
                     prefill_cache=True).run(measure_ops)
        rows = _tenant_rows(r)
        rel_err = max(abs(st.share / st.weight_share - 1.0)
                      for st in r.tenant_stats.values())
        out[f"{w0:g}:{w1:g}"] = {
            "iops": float(r.iops),
            "share_error": float(r.share_error),
            "max_rel_share_error": float(rel_err),
            "tenants": rows,
        }
        print(f"  weights {w0:g}:{w1:g}  shares "
              f"{r.tenant_stats[0].share:.3f}/{r.tenant_stats[1].share:.3f}"
              f"  (want {r.tenant_stats[0].weight_share:.3f}/"
              f"{r.tenant_stats[1].weight_share:.3f})"
              f"  rel err {rel_err * 100:.1f}%  {r.iops:9,.0f} IOPS")
    return out


def slo_protection(n_ssds, qd, ops_per_ssd, seed=0):
    """Protected Zipf reader vs GC-driving writer, with/without the SLO."""
    measure_ops = ops_per_ssd * n_ssds
    W = n_ssds * qd // 2
    wl = Workload(w_total=W, qd_per_ssd=W)
    reader = dict(weight=1.0, read_frac=1.0, dist="zipf")

    def run(slo_p99):
        # protection-tuned controller: a long sliding window keeps episode
        # samples visible (violations stay continuous), frequent checks and
        # a low recovery threshold hold the writer in deep throttle until
        # the tail has actually cleared — GC pause fraction must fall below
        # ~1% before a p99 can drop under the episode scale
        pol = QosPolicy(tenants=(TenantSpec(0, slo_p99=slo_p99, **reader),
                                 TenantSpec(1, weight=1.0)),
                        slo_window_ops=512, slo_check_ops=32,
                        throttle_recover=0.5)
        r = ArraySim(n_ssds, SSD, 0.6, wl, seed=seed, qos=pol,
                     prefill_cache=True).run(measure_ops)
        return r

    base = run(None)                       # telemetry-only: no enforcement
    base_p99 = base.tenant_stats[0].p99_latency
    # an SLO well below the interference tail forces the controller into
    # the deep-throttle regime (violations nearly continuous), where GC
    # goes quiet enough for the reader's p99 to actually clear
    slo = base_p99 * 0.2
    prot = run(slo)
    out = {
        "slo_p99_ms": 1e3 * slo,
        "no_qos": {
            "reader_p99_ms": 1e3 * base_p99,
            "writer_share": float(base.tenant_stats[1].share),
            "gc_pause_frac": float(np.mean(base.gc_pause_frac)),
            "tenants": _tenant_rows(base),
        },
        "qos": {
            "reader_p99_ms": 1e3 * prot.tenant_stats[0].p99_latency,
            "writer_share": float(prot.tenant_stats[1].share),
            "writer_throttle_time_ms":
                1e3 * prot.tenant_stats[1].throttle_time,
            "gc_pause_frac": float(np.mean(prot.gc_pause_frac)),
            "tenants": _tenant_rows(prot),
        },
    }
    print(f"  reader p99: {out['no_qos']['reader_p99_ms']:6.2f} ms unprotected"
          f" -> {out['qos']['reader_p99_ms']:6.2f} ms with SLO "
          f"{out['slo_p99_ms']:.2f} ms  (writer share "
          f"{out['no_qos']['writer_share']:.2f} -> "
          f"{out['qos']['writer_share']:.2f}, throttled "
          f"{out['qos']['writer_throttle_time_ms']:.0f} ms, gc frac "
          f"{out['no_qos']['gc_pause_frac']:.3f} -> "
          f"{out['qos']['gc_pause_frac']:.3f})")
    return out


def scale_tenants(n_ssds, qd, ops_per_ssd, n_shards, seed=0):
    """3-tenant mix on the sharded path; serial == parallel bit-identical."""
    measure_ops = ops_per_ssd * n_ssds
    W = n_ssds * qd // 2
    wl = Workload(w_total=W, qd_per_ssd=W)
    pol = QosPolicy(tenants=(
        TenantSpec(0, weight=2.0, read_frac=1.0, dist="zipf", slo_p99=2e-3),
        TenantSpec(1, weight=2.0),
        TenantSpec(2, weight=1.0, rate_iops=4000.0 * n_ssds, burst=64.0),
    ))

    def run(parallel):
        sim = ShardedArraySim(n_ssds, SSD, 0.6, wl, seed=seed,
                              n_shards=n_shards, parallel=parallel, qos=pol)
        return sim.run(measure_ops)

    par = run(True)
    ser = run(False)
    identical = all(
        (par.tenant_stats[t].ops, par.tenant_stats[t].throughput,
         par.tenant_stats[t].mean_latency, par.tenant_stats[t].p50_latency,
         par.tenant_stats[t].p95_latency, par.tenant_stats[t].p99_latency,
         par.tenant_stats[t].throttle_time) ==
        (ser.tenant_stats[t].ops, ser.tenant_stats[t].throughput,
         ser.tenant_stats[t].mean_latency, ser.tenant_stats[t].p50_latency,
         ser.tenant_stats[t].p95_latency, ser.tenant_stats[t].p99_latency,
         ser.tenant_stats[t].throttle_time)
        for t in pol.ids) and par.iops == ser.iops
    out = {
        "n_shards": n_shards,
        "iops": float(par.iops),
        "serial_equals_sharded": identical,
        "all_tenants_served": all(par.tenant_stats[t].ops > 0
                                  for t in pol.ids),
        "tenants": _tenant_rows(par),
    }
    print(f"  3 tenants x {n_ssds} SSDs x {n_shards} shards: "
          f"{par.iops:9,.0f} IOPS  serial==sharded "
          f"{'OK' if identical else 'MISMATCH'}  per-tenant ops "
          + "/".join(str(par.tenant_stats[t].ops) for t in pol.ids))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small array (< 1 min), for CI / tests")
    ap.add_argument("--n-ssds", type=int, default=None)
    ap.add_argument("--qd", type=int, default=None)
    ap.add_argument("--ops-per-ssd", type=int, default=None)
    ap.add_argument("--shards", type=int, default=None,
                    help="worker shard count for the scale section (pinned "
                         "per tier — results are deterministic only for a "
                         "fixed (seed, n_shards))")
    ap.add_argument("--out", default=str(ROOT / "BENCH_qos.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        n_ssds = args.n_ssds or 6
        qd = args.qd or 32
        ops = args.ops_per_ssd or 800
        n_shards = args.shards or 2
    else:
        n_ssds = args.n_ssds or 12
        qd = args.qd or 32
        ops = args.ops_per_ssd or 1500
        n_shards = args.shards or 3

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_ssds": n_ssds,
        "qd": qd,
        "ops_per_ssd": ops,
    }
    print(f"weight sweep ({n_ssds} SSDs, qd {qd}, occupancy 0.6):")
    result["weight_sweep"] = weight_sweep(n_ssds, qd, ops)
    print("SLO protection (Zipf reader vs GC-driving writer):")
    result["slo_protection"] = slo_protection(n_ssds, qd, ops)
    print("multi-tenant scale (sharded):")
    result["scale_tenants"] = scale_tenants(n_ssds, qd, ops, n_shards)
    result["wall_s"] = time.perf_counter() - t0

    sp = result["slo_protection"]
    checks = {
        # achieved shares track configured weights within 10% relative
        "shares_track_weights_10pct": all(
            row["max_rel_share_error"] <= 0.10
            for row in result["weight_sweep"].values()),
        # the protected reader's p99 under active GC improves with QoS
        "slo_improves_reader_p99":
            sp["qos"]["reader_p99_ms"] < sp["no_qos"]["reader_p99_ms"],
        # ... because the controller actually throttled the writer
        "writer_throttled":
            sp["qos"]["writer_throttle_time_ms"] > 0.0
            and sp["qos"]["writer_share"] < sp["no_qos"]["writer_share"],
        # per-tenant stats merge exactly across worker processes
        "serial_equals_sharded":
            result["scale_tenants"]["serial_equals_sharded"],
        "all_tenants_served": result["scale_tenants"]["all_tenants_served"],
    }
    result["checks"] = checks
    ok = all(checks.values())
    result["all_checks_pass"] = ok

    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_qos", result)
    print(f"qos sweep done in {result['wall_s']:.1f}s; checks: "
          + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                      for k, v in checks.items()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

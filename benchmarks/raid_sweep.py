"""Array-layout sweeps: the paper's GC-imbalance story magnified by stripe
synchronization (JBOD vs RAID-0 vs RAID-5 on the same SSDs).

Under JBOD an unsynchronized GC pause stalls only the requests of ONE SSD;
under striping a stripe write completes at the MAX of its members, so any
member mid-GC stalls every stripe touching it — and RAID-5's read-modify-
write turns each small random write into 2 reads + 2 writes spread over
sibling SSDs (parity WA 2x on top of GC WA). The sweep quantifies:

* ``qd_sweep`` — p99 latency of (full-)stripe writes and throughput vs
  per-SSD queue depth under active GC, per layout, with the array write
  amplification split into GC-WA x parity-WA.
* ``sequential`` — full-stripe coalescing: sequential runs skip the RMW, so
  RAID-5's parity WA drops from ~2 to ~(g)/(g-1).
* ``stall_vs_gc`` — the stripe-stall metric (last member completion minus
  first, per striped write) with GC idle vs active: stripe synchronization
  is cheap until unsynchronized GC makes members diverge.
* ``degraded_rebuild`` — RAID-5 with a failed member: reconstruction reads,
  then rebuild traffic competing with foreground I/O.

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.raid_sweep            # 18 SSDs
    PYTHONPATH=src python -m benchmarks.raid_sweep --smoke    # 6 SSDs, CI
    PYTHONPATH=src python -m benchmarks.raid_sweep --n-ssds 36 --qds 4 32

Writes ``BENCH_raid.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.gc_sim import Workload
from repro.core.raid import JBODLayout, Raid0Layout, Raid5Layout
from repro.core.sharded import ShardedArraySim

from .common import SSD, save

ROOT = Path(__file__).resolve().parent.parent


def _point(n_ssds, layout, wl, occupancy, measure_ops, n_shards, seed=0):
    sim = ShardedArraySim(n_ssds, SSD, occupancy, wl, seed=seed,
                          n_shards=n_shards, layout=layout)
    r = sim.run(measure_ops)
    return r, sim.last_wall_s


def _row(r, wall_s):
    # measured pages per write op, NOT the nominal stripe_width: the planner
    # clamps the width to the group's data width and emits short tail
    # windows (group=6, w=4 -> alternating 4- and 2-page ops, mean ~3.33)
    write_ops = r.write_iops * r.sim_time
    pages_per_op = r.logical_writes / write_ops \
        if r.logical_writes and write_ops else 1.0
    return {
        "iops": float(r.iops),
        # compare layouts on page_iops — raid0's multi-page logical ops make
        # its raw iops a different unit than jbod/raid5's 1-page ops
        "pages_per_op": pages_per_op,
        "page_iops": float(r.iops) * pages_per_op,
        "p50_ms": 1e3 * r.p50_latency,
        "p99_ms": 1e3 * r.p99_latency,
        "parity_wa": float(r.parity_wa),
        "gc_wa": float(r.gc_wa),
        "array_wa": float(r.array_wa),
        "stall_mean_ms": 1e3 * r.stripe_stall_mean,
        "stall_p99_ms": 1e3 * r.stripe_stall_p99,
        "util_spread": float(r.util_spread),
        "gc_pause_frac": float(np.mean(r.gc_pause_frac)),
        "rmw_ops": int(r.rmw_ops),
        "full_stripe_rows": int(r.full_stripe_rows),
        "events": int(r.events),
        "wall_s": float(wall_s),
    }


def qd_sweep(n_ssds, group, qds, ops_per_ssd, n_shards):
    """Uniform 4K random writes at occupancy 0.6 (active GC), per layout."""
    measure_ops = ops_per_ssd * n_ssds
    layouts = {
        "jbod": JBODLayout(),
        "raid0": Raid0Layout(stripe_width=4, group=group),
        "raid5": Raid5Layout(stripe_width=1, group=group),
    }
    out = {}
    for name, layout in layouts.items():
        rows = {"qd": [], "rows": []}
        for qd in qds:
            wl = Workload(w_total=n_ssds * qd, qd_per_ssd=qd,
                          n_streams=n_ssds)
            r, wall = _point(n_ssds, layout, wl, 0.6, measure_ops, n_shards)
            rows["qd"].append(qd)
            row = _row(r, wall)
            rows["rows"].append(row)
            print(f"  {name:6s} qd={qd:4d}: {row['page_iops']:9,.0f} pages/s"
                  f" ({r.iops:9,.0f} x {row['pages_per_op']:.2f}p ops)  "
                  f"p99 {1e3 * r.p99_latency:6.2f} ms  "
                  f"parity_wa {r.parity_wa:.2f}  gc_wa {r.gc_wa:.2f}  "
                  f"stall_p99 {1e3 * r.stripe_stall_p99:5.2f} ms")
        out[name] = rows
    return out


def sequential_coalescing(n_ssds, group, qd, ops_per_ssd, n_shards):
    """RAID-5 parity WA: uniform small writes (RMW) vs sequential streams
    (full-stripe coalescing)."""
    measure_ops = ops_per_ssd * n_ssds
    layout = Raid5Layout(stripe_width=1, group=group)
    out = {}
    for scen, wl in (
        ("uniform", Workload(w_total=n_ssds * qd, qd_per_ssd=qd,
                             n_streams=n_ssds)),
        ("sequential", Workload(w_total=n_ssds * qd, qd_per_ssd=qd,
                                n_streams=n_ssds, scenario="sequential",
                                seq_streams=4)),
    ):
        r, wall = _point(n_ssds, layout, wl, 0.6, measure_ops, n_shards)
        out[scen] = _row(r, wall)
        print(f"  raid5/{scen:10s}: parity_wa {r.parity_wa:.3f}  "
              f"rmw {r.rmw_ops}  full-stripe rows {r.full_stripe_rows}")
    return out


def stall_vs_gc(n_ssds, group, qd, ops_per_ssd, n_shards):
    """Stripe-stall with GC idle (occupancy 0.05 never trips the watermark)
    vs active (0.6): member divergence is what striping pays for."""
    measure_ops = ops_per_ssd * n_ssds
    layout = Raid5Layout(stripe_width=1, group=group)
    wl = Workload(w_total=n_ssds * qd, qd_per_ssd=qd, n_streams=n_ssds)
    out = {}
    for tag, occ in (("gc_idle", 0.05), ("gc_active", 0.6)):
        r, wall = _point(n_ssds, layout, wl, occ, measure_ops, n_shards)
        out[tag] = _row(r, wall)
        print(f"  raid5/{tag:9s}: stall p99 {1e3 * r.stripe_stall_p99:6.3f} ms"
              f"  (gc pause frac {np.mean(r.gc_pause_frac):.3f})")
    return out


def degraded_rebuild(n_ssds, group, qd, ops_per_ssd, n_shards):
    """RAID-5 failure scenarios: healthy vs degraded vs degraded+rebuild."""
    measure_ops = ops_per_ssd * n_ssds
    wl = Workload(w_total=n_ssds * qd, qd_per_ssd=qd, n_streams=n_ssds,
                  read_frac=0.5)
    out = {}
    for tag, layout in (
        ("healthy", Raid5Layout(group=group)),
        ("degraded", Raid5Layout(group=group, degraded=1)),
        ("rebuild", Raid5Layout(group=group, degraded=1, rebuild=True)),
    ):
        r, wall = _point(n_ssds, layout, wl, 0.6, measure_ops, n_shards)
        row = _row(r, wall)
        row["degraded_reads"] = int(r.degraded_reads)
        row["rebuild_rows"] = int(r.rebuild_rows)
        out[tag] = row
        print(f"  raid5/{tag:9s}: {r.iops:9,.0f} IOPS  "
              f"p99 {1e3 * r.p99_latency:6.2f} ms  "
              f"degraded reads {r.degraded_reads}  "
              f"rebuild rows {r.rebuild_rows}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small array (< 1 min), for CI / tests")
    ap.add_argument("--n-ssds", type=int, default=None)
    ap.add_argument("--group", type=int, default=None,
                    help="SSDs per RAID group (must divide n-ssds)")
    ap.add_argument("--qds", type=int, nargs="+", default=None)
    ap.add_argument("--ops-per-ssd", type=int, default=None)
    ap.add_argument("--shards", type=int, default=None,
                    help="worker shard count (pinned per tier, NOT cpu_count "
                         "— results are deterministic only for a fixed "
                         "(seed, n_shards); shard sizes snap to whole stripe "
                         "groups)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_raid.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        n_ssds = args.n_ssds or 6
        group = args.group or 3
        qds = args.qds or (4, 32)
        ops = args.ops_per_ssd or 300
        n_shards = args.shards or 2
    else:
        n_ssds = args.n_ssds or 18
        group = args.group or 6
        qds = args.qds or (1, 4, 32, 128)
        ops = args.ops_per_ssd or 600
        n_shards = args.shards or 3
    mid_qd = qds[len(qds) // 2]

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_ssds": n_ssds,
        "group": group,
        "n_shards": n_shards,
        "qds": list(qds),
        "ops_per_ssd": ops,
    }
    print(f"qd sweep ({n_ssds} SSDs, group {group}, occupancy 0.6):")
    result["qd_sweep"] = qd_sweep(n_ssds, group, qds, ops, n_shards)
    print("sequential coalescing:")
    result["sequential"] = sequential_coalescing(n_ssds, group, mid_qd, ops,
                                                 n_shards)
    print("stripe stall vs GC:")
    result["stall_vs_gc"] = stall_vs_gc(n_ssds, group, mid_qd, ops, n_shards)
    print("degraded + rebuild:")
    result["degraded_rebuild"] = degraded_rebuild(n_ssds, group, mid_qd, ops,
                                                  n_shards)
    result["wall_s"] = time.perf_counter() - t0

    sweep = result["qd_sweep"]
    raid5_rows = sweep["raid5"]["rows"]
    checks = {
        # RAID-5 small random writes pay the RMW: parity WA ~2 (> 1)
        "raid5_parity_wa_gt_1": all(row["parity_wa"] > 1.0
                                    for row in raid5_rows),
        # full-stripe coalescing lowers parity WA on sequential workloads
        "seq_coalescing_reduces_parity_wa":
            result["sequential"]["sequential"]["parity_wa"]
            < result["sequential"]["uniform"]["parity_wa"],
        # stripe stall grows once unsynchronized GC desynchronizes members
        "stall_increases_under_gc":
            result["stall_vs_gc"]["gc_active"]["stall_p99_ms"]
            > result["stall_vs_gc"]["gc_idle"]["stall_p99_ms"],
        # JBOD carries no parity WA by construction
        "jbod_parity_wa_is_1": all(row["parity_wa"] == 1.0
                                   for row in sweep["jbod"]["rows"]),
        # failure scenarios actually exercised: degraded mode reconstructs
        # reads, the rebuild tenant streams rows. (iops ordering is NOT
        # gated: at 50% reads, degraded writes get cheaper — parity-dead
        # rows skip the RMW — while reads get dearer, so the sign is
        # GC-phase noise.)
        "degraded_reconstruction_active":
            result["degraded_rebuild"]["degraded"]["degraded_reads"] > 0
            and result["degraded_rebuild"]["rebuild"]["rebuild_rows"] > 0,
    }
    result["checks"] = checks
    ok = all(checks.values())
    result["all_checks_pass"] = ok

    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_raid", result)
    print(f"raid sweep done in {result['wall_s']:.1f}s; checks: "
          + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                      for k, v in checks.items()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

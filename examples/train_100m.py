"""End-to-end driver: train a ~100M-param TinyLlama-family model for a few
hundred steps with the full production stack (sharded pjit step, prefetched
synthetic data, async flusher-backed checkpointing, resume).

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

~100M params: 12L d_model=768 12H kv=4 d_ff=2048 vocab=32000.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch import train as T

CFG_100M = dataclasses.replace(
    get_config("tinyllama-1.1b"),
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    vocab=32000, head_dim=64, dtype="float32", max_seq=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    print(f"~{CFG_100M.param_count() / 1e6:.0f}M parameters")
    # monkey-patch the driver's config resolution to inject the 100M config
    orig = T.get_config
    T.get_config = lambda name: CFG_100M
    orig_reduced = T.reduced
    T.reduced = lambda cfg, **kw: cfg
    try:
        T.main(["--arch", "tinyllama-1.1b", "--preset", "smoke",
                "--steps", str(args.steps), "--batch", str(args.batch),
                "--seq", str(args.seq), "--lr", "3e-4",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"])
    finally:
        T.get_config = orig
        T.reduced = orig_reduced


if __name__ == "__main__":
    main()

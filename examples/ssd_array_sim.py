"""Reproduce the paper's headline experiment interactively: an 8-SSD array
under GC, with and without the dirty-page flusher — then show the two new
levers the unified engine exposes: per-SSD queue depth (the paper's Figure-3
dynamic) and workload scenarios (bursty / mixed multi-tenant).

  PYTHONPATH=src python examples/ssd_array_sim.py
"""
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.safs_sim import SAFSSim, SAFSWorkload

SSD = SSDParams(capacity_pages=8192)

print("8 SSDs, 80% full, 4K uniform random writes, async (128 in flight)\n")
for use_flusher in (False, True):
    sim = SAFSSim(n_ssds=8, ssd=SSD, occupancy=0.8,
                  workload=SAFSWorkload(read_frac=0.0, concurrency=256),
                  cache_frac=0.1, use_flusher=use_flusher, seed=0)
    r = sim.run(20000)
    print(f"flusher={'ON ' if use_flusher else 'OFF'}  "
          f"app IOPS={r.app_iops:,.0f}  hit={r.hit_rate * 100:.1f}%  "
          f"flush={r.flush_writes}  demand(blocking)={r.demand_writes}  "
          f"stale discards={r.stale_discards}")
    print(f"             latency p50/p95/p99: "
          f"{r.p50_latency * 1e3:.2f}/{r.p95_latency * 1e3:.2f}/"
          f"{r.p99_latency * 1e3:.2f} ms   per-SSD utilization: "
          f"{[f'{u:.2f}' for u in r.util]}")

print("\nqueue depth hides unsynchronized GC (8 SSDs, 60% full, raw writes):\n")
for qd in (1, 4, 32, 128):
    r = ArraySim(8, SSD, 0.6,
                 Workload(w_total=8 * qd, qd_per_ssd=qd, n_streams=8),
                 seed=0).run(15000)
    print(f"qd={qd:4d}  IOPS={r.iops:10,.0f}  "
          f"p50={r.p50_latency * 1e3:6.2f} ms  p99={r.p99_latency * 1e3:6.2f} ms  "
          f"GC pause frac={r.gc_pause_frac.mean():.2f}")

print("\nscenario layer (same array, same engine):\n")
for scenario in ("random", "sequential", "bursty", "mixed"):
    wl = Workload(w_total=256, qd_per_ssd=64, n_streams=8, scenario=scenario,
                  burst_on=1e-3, burst_off=1e-3, writer_frac=0.5)
    r = ArraySim(8, SSD, 0.6, wl, seed=0).run(15000)
    print(f"{scenario:10s}  IOPS={r.iops:10,.0f}  "
          f"reads={r.read_iops:9,.0f}  writes={r.write_iops:9,.0f}  "
          f"p99={r.p99_latency * 1e3:6.2f} ms")

"""Reproduce the paper's headline experiment interactively: an 8-SSD array
under GC, with and without the dirty-page flusher — then show the levers the
unified engine exposes: per-SSD queue depth (the paper's Figure-3 dynamic),
workload scenarios (bursty / mixed multi-tenant), phased hot/cold scenarios
(precondition -> write burst -> drain, per-phase cache/writeback stats),
array layouts (RAID-0/RAID-5 striping with a degraded + rebuilding RAID-5
group), per-tenant QoS (a reader's p99 SLO protected against a
GC-driving writer), fault drills (a fail-slow member tamed by hedged
reads + quarantine, and a mid-run crash -> degraded reads -> rebuild -> heal),
and a telemetry drill (reactive vs staggered GC on the RAID-5 tier with the
latency budget side by side, plus a Perfetto trace of a GC episode).
Finally, a serving-fleet drill: a synthetic LLM fleet drives the paged KV
pool through the recording shim, and the emitted KV-spill trace replays on
the sharded array under reactive vs staggered GC.

  PYTHONPATH=src python examples/ssd_array_sim.py
"""
from pathlib import Path

import numpy as np

from repro.core.faults import Crash, FailSlow, FaultPolicy
from repro.core.gc_coord import ReactiveGc, StaggeredGc
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.raid import Raid0Layout, Raid5Layout
from repro.core.safs_sim import SAFSSim, SAFSWorkload
from repro.core.sharded import ShardedArraySim
from repro.core.telemetry import TelemetrySpec
from repro.core.workloads import HotColdSource, Phase
from repro.serving.fleet import FleetConfig, run_fleet

SSD = SSDParams(capacity_pages=8192)

print("8 SSDs, 80% full, 4K uniform random writes, async (128 in flight)\n")
for use_flusher in (False, True):
    sim = SAFSSim(n_ssds=8, ssd=SSD, occupancy=0.8,
                  workload=SAFSWorkload(read_frac=0.0, concurrency=256),
                  cache_frac=0.1, use_flusher=use_flusher, seed=0)
    r = sim.run(20000)
    print(f"flusher={'ON ' if use_flusher else 'OFF'}  "
          f"app IOPS={r.app_iops:,.0f}  hit={r.hit_rate * 100:.1f}%  "
          f"flush={r.flush_writes}  demand(blocking)={r.demand_writes}  "
          f"stale discards={r.stale_discards}")
    print(f"             latency p50/p95/p99: "
          f"{r.p50_latency * 1e3:.2f}/{r.p95_latency * 1e3:.2f}/"
          f"{r.p99_latency * 1e3:.2f} ms   per-SSD utilization: "
          f"{[f'{u:.2f}' for u in r.util]}")

print("\nqueue depth hides unsynchronized GC (8 SSDs, 60% full, raw writes):\n")
for qd in (1, 4, 32, 128):
    r = ArraySim(8, SSD, 0.6,
                 Workload(w_total=8 * qd, qd_per_ssd=qd, n_streams=8),
                 seed=0).run(15000)
    print(f"qd={qd:4d}  IOPS={r.iops:10,.0f}  "
          f"p50={r.p50_latency * 1e3:6.2f} ms  p99={r.p99_latency * 1e3:6.2f} ms  "
          f"GC pause frac={r.gc_pause_frac.mean():.2f}")

print("\nscenario layer (same array, same engine):\n")
for scenario in ("random", "sequential", "bursty", "mixed"):
    wl = Workload(w_total=256, qd_per_ssd=64, n_streams=8, scenario=scenario,
                  burst_on=1e-3, burst_off=1e-3, writer_frac=0.5)
    r = ArraySim(8, SSD, 0.6, wl, seed=0).run(15000)
    print(f"{scenario:10s}  IOPS={r.iops:10,.0f}  "
          f"reads={r.read_iops:9,.0f}  writes={r.write_iops:9,.0f}  "
          f"p99={r.p99_latency * 1e3:6.2f} ms")

print("\nphased hot/cold SAFS scenario (8 SSDs, 80% full): precondition the "
      "cache\nwith the hot set, hit it with a write burst, then drain under "
      "hot reads —\none measurement window per phase, cache/flusher state "
      "carried across:\n")
phased = SAFSSim(n_ssds=8, ssd=SSD, occupancy=0.8,
                 workload=SAFSWorkload(concurrency=256), cache_frac=0.1,
                 use_flusher=True, seed=0)
rng = np.random.default_rng(42)
n_live = phased.n_live
hot = dict(hot_frac=0.1, hot_ops=0.9)
for name, r in phased.run_phased([
        # unmeasured warm-up: populate the cache with the hot working set
        Phase("precondition", HotColdSource(n_live, rng, read_frac=0.5, **hot),
              12000, measure=False),
        Phase("write burst", HotColdSource(n_live, rng, read_frac=0.0, **hot),
              8000, warmup=1000),
        Phase("drain", HotColdSource(n_live, rng, read_frac=0.9, **hot),
              8000, warmup=1000)]):
    wb = r.flush_writes + r.demand_writes
    print(f"{name:12s}  app IOPS={r.app_iops:9,.0f}  "
          f"hit={r.hit_rate * 100:5.1f}%  writeback={wb:5d} pages "
          f"(demand {r.demand_writes})  p99={r.p99_latency * 1e3:5.2f} ms")

print("\narray layouts (8 SSDs, 60% full): striping synchronizes on the "
      "slowest member,\nand RAID-5 parity amplifies small writes "
      "(array WA = parity WA x GC WA):\n")
WL = Workload(w_total=256, qd_per_ssd=32, n_streams=8)
for name, layout in (("jbod", None),
                     ("raid0", Raid0Layout(stripe_width=4, group=8)),
                     ("raid5", Raid5Layout(group=8))):
    r = ArraySim(8, SSD, 0.6, WL, seed=0, layout=layout).run(12000)
    # raid0 logical ops cover several pages: compare layouts in pages/s
    # (measured page rate, since the planner clamps widths to the stripe row)
    pages_s = r.logical_writes / r.sim_time if r.logical_writes else r.iops
    print(f"{name:6s}  pages/s={pages_s:9,.0f}  "
          f"p99={r.p99_latency * 1e3:6.2f} ms  "
          f"parity WA={r.parity_wa:.2f}  GC WA={r.gc_wa:.2f}  "
          f"array WA={r.array_wa:.2f}  stripe-stall p99="
          f"{r.stripe_stall_p99 * 1e3:5.2f} ms")

print("\nRAID-5 failure drill (8 SSDs, one failed member, 50% reads): "
      "degraded reads\nreconstruct from the 7 survivors; the rebuild tenant "
      "then streams row\nreconstruction I/O against foreground traffic:\n")
WL_RW = Workload(w_total=256, qd_per_ssd=32, n_streams=8, read_frac=0.5)
for tag, layout in (
        ("healthy", Raid5Layout(group=8)),
        ("degraded", Raid5Layout(group=8, degraded=1)),
        ("rebuilding", Raid5Layout(group=8, degraded=1, rebuild=True))):
    r = ArraySim(8, SSD, 0.6, WL_RW, seed=0, layout=layout).run(12000)
    print(f"{tag:10s}  IOPS={r.iops:9,.0f}  p99={r.p99_latency * 1e3:6.2f} ms  "
          f"reconstructed reads={r.degraded_reads:5d}  "
          f"rebuilt rows={r.rebuild_rows}")

print("\nper-tenant QoS (8 SSDs, 60% full): a Zipf reader shares the array "
      "with a\nrandom writer whose traffic drives GC. Without an SLO the "
      "reader's p99 rides\nthe GC episodes; with one, the controller "
      "throttles the writer until the\ntail clears:\n")
READER = dict(weight=1.0, read_frac=1.0, dist="zipf")
WL_QOS = Workload(w_total=128, qd_per_ssd=128)
for tag, slo in (("no SLO ", None), ("SLO 0.6ms", 0.6e-3)):
    policy = QosPolicy(
        tenants=(TenantSpec(0, slo_p99=slo, **READER),
                 TenantSpec(1, weight=1.0)),
        slo_window_ops=512, slo_check_ops=32, throttle_recover=0.5)
    r = ArraySim(8, SSD, 0.6, WL_QOS, seed=0, qos=policy).run(15000)
    reader, writer = r.tenant_stats[0], r.tenant_stats[1]
    print(f"{tag}  reader p99={reader.p99_latency * 1e3:5.2f} ms  "
          f"writer share={writer.share:.2f}  "
          f"writer throttled={writer.throttle_time * 1e3:5.1f} ms  "
          f"GC pause frac={r.gc_pause_frac.mean():.3f}")

print("\nfail-slow drill (8 SSDs RAID-5, read-only, member 0 serving 6x "
      "slow):\nundefended, the submission streams head-of-line block behind "
      "the sick\nmember and its healthy peers starve; with hedged reads + "
      "the peer-relative\ndetector, late reads reconstruct from siblings "
      "and the suspect is\nquarantined (admission capped, reads steered "
      "away):\n")
SLOW = FailSlow(device=0, onset=0.0, slow_factor=6.0)
WL_RO = Workload(w_total=64, qd_per_ssd=32, n_streams=8, read_frac=1.0)
for tag, faults in (
        ("no defense", FaultPolicy(events=(SLOW,))),
        ("defended  ", FaultPolicy(events=(SLOW,), hedge_after=1.5e-3,
                                   detect=True, detect_min_samples=32,
                                   detect_every=32, quarantine_qd=16))):
    r = ArraySim(8, SSD, 0.6, WL_RO, seed=0, layout=Raid5Layout(group=8),
                 faults=faults).run(15000)
    f = r.faults
    peers = min(u for i, u in enumerate(r.util) if i != SLOW.device)
    print(f"{tag}  IOPS={r.iops:9,.0f}  p99={r.p99_latency * 1e3:5.2f} ms  "
          f"peer util_min={peers:.2f}  "
          f"hedges={f['hedged_reads']} ({f['hedge_wins']} won)  "
          f"quarantined {f['quarantine_time_s'] * 1e3:.0f} ms")

print("\nmid-run crash drill (8 SSDs RAID-5, small members so the rebuild "
      "finishes\nin-run): member 2 dies at t=5ms, its group plans degraded "
      "from the crash\non, the rebuild tenant spawns at crash time, and the "
      "group heals when the\nspare holds every row:\n")
SMALL = SSDParams(capacity_pages=2048)
r = ArraySim(8, SMALL, 0.5,
             Workload(w_total=64, qd_per_ssd=32, n_streams=8, read_frac=0.5),
             seed=0, layout=Raid5Layout(group=8),
             faults=FaultPolicy(events=(Crash(device=2, at_time=5e-3),))
             ).run(40000)
f = r.faults
print(f"crash@{f['crash_at'] * 1e3:.1f} ms -> rebuilt@"
      f"{f['rebuild_completed_at'] * 1e3:.1f} ms "
      f"(data at risk {f['data_at_risk_s'] * 1e3:.1f} ms)  "
      f"rebuilt rows={r.rebuild_rows}  "
      f"reconstructed reads={r.degraded_reads}  "
      f"foreground IOPS={r.iops:,.0f}  p99={r.p99_latency * 1e3:.2f} ms")

print("\ntelemetry drill (8 SSDs RAID-5, 60% full, write-heavy): the "
      "gc_active probe\nseries catches reactive GC synchronizing across "
      "members (all-devices-GC\nticks) while the staggered lease rotates; "
      "span tracing decomposes each\npolicy's mean latency into the same "
      "additive budget — park + queue + gc +\nservice + sync — so the tail "
      "shows up as a named wait, not a mystery:\n")
TEL = TelemetrySpec(series_dt=1e-4, spans=True)
WL_TEL = Workload(w_total=256, qd_per_ssd=32, n_streams=8)
tel_runs = {}
for tag, gc in (("reactive", ReactiveGc()),
                ("staggered", StaggeredGc(max_concurrent=1))):
    r = ArraySim(8, SSD, 0.6, WL_TEL, seed=0, layout=Raid5Layout(group=8),
                 gc=gc, telemetry=TEL).run(15000)
    tel_runs[tag] = r
    t = r.telemetry
    print(f"{tag:10s}  all-devices-GC ticks={int(t.gc_active_all().sum()):5d}"
          f"  any-GC ticks={int(t.gc_active_any().sum()):5d}  "
          f"episodes={len(t.gc_episodes):4d}  "
          f"p99={r.p99_latency * 1e3:5.2f} ms")

comps = list(tel_runs["reactive"].telemetry.budget["mean"])
print("\nlatency budget, mean us/op (components sum to the measured mean):\n")
print(f"{'':10s}" + "".join(f"{c:>10s}" for c in comps) + f"{'= mean':>10s}")
for tag in ("reactive", "staggered"):
    bud = tel_runs[tag].telemetry.budget
    print(f"{tag:10s}"
          + "".join(f"{1e6 * bud['mean'][c]:10.1f}" for c in comps)
          + f"{1e6 * bud['mean_latency']:10.1f}")

# Perfetto trace of the staggered run: zoom to the printed episode window
# at https://ui.perfetto.dev ("Open trace file") to watch one GC lease
# block a single member while its peers keep serving.
trace_dir = Path(__file__).resolve().parent.parent / "experiments"
trace_dir.mkdir(exist_ok=True)
trace_path = trace_dir / "telemetry_gc_episode_trace.json"
t = tel_runs["staggered"].telemetry
n_events = t.export_trace(trace_path)
dev, t0, t1, _idle = t.gc_episodes[0]
print(f"\nwrote {n_events} trace events -> {trace_path}")
print(f"first GC episode: device {dev}, "
      f"{t0 * 1e3:.3f} -> {t1 * 1e3:.3f} ms "
      f"({(t1 - t0) * 1e6:.0f} us lease)")

print("\nserving-fleet drill: a synthetic LLM fleet (interactive + batch "
      "tenants)\ndrives the paged KV pool; every offload, resume fetch and "
      "dirty-eviction\nspill that reaches a device is recorded as a "
      "(time, lba, op, tenant) trace,\nthen replayed — time-compressed "
      "100x — on a 16-SSD sharded array under\nper-tenant QoS and two GC "
      "policies:\n")
fleet = run_fleet(FleetConfig(n_targets=16, duration_s=0.4,
                              arrival_rate=500.0, pool_sets=8, set_size=8,
                              flush_trigger=1), seed=0)
tr = fleet.trace
print(f"emitted {len(tr)} trace rows from {fleet.sessions_started} sessions "
      f"({int((tr[:, 2] == 1).sum())} spills, "
      f"{int((tr[:, 2] == 0).sum())} fetches, "
      f"{fleet.stale_discards} stale flushes discarded at the queue head)")
KV_WL = Workload(scenario="trace", w_total=128, qd_per_ssd=8, n_streams=16,
                 trace_time_scale=0.01)
KV_QOS = QosPolicy(tenants=(TenantSpec(0, 2.0, slo_p99=4e-3),
                            TenantSpec(1, 1.0, slo_p99=20e-3)))
SMALL_KV = SSDParams(capacity_pages=4096)
for tag, gc in (("reactive ", ReactiveGc()),
                ("staggered", StaggeredGc(max_concurrent=1, scope="group",
                                          early_blocks=4))):
    # parallel=False keeps this script spawn-safe; the sharded decomposition
    # (and its results) are identical either way.
    r = ShardedArraySim(16, SMALL_KV, 0.8, KV_WL, seed=3, n_shards=2,
                        trace=tr, qos=KV_QOS, gc=gc, parallel=False
                        ).run(16 * 500)
    inter = r.tenant_stats[0]
    print(f"{tag}  tokens/s={r.write_iops * fleet.meta['page_tokens']:12,.0f}"
          f"  p99 spill={r.p99_latency * 1e3:5.2f} ms  "
          f"interactive p99={inter.p99_latency * 1e3:5.2f} ms "
          f"(SLO 4 ms {'met' if inter.p99_latency <= 4e-3 else 'MISSED'})  "
          f"GC pause frac={r.gc_pause_frac.mean():.3f}")

"""Reproduce the paper's headline experiment interactively: an 8-SSD array
under GC, with and without the dirty-page flusher.

  PYTHONPATH=src python examples/ssd_array_sim.py
"""
from repro.core.gc_sim import SSDParams
from repro.core.safs_sim import SAFSSim, SAFSWorkload

SSD = SSDParams(capacity_pages=8192)

print("8 SSDs, 80% full, 4K uniform random writes, async (128 in flight)\n")
for use_flusher in (False, True):
    sim = SAFSSim(n_ssds=8, ssd=SSD, occupancy=0.8,
                  workload=SAFSWorkload(read_frac=0.0, concurrency=256),
                  cache_frac=0.1, use_flusher=use_flusher, seed=0)
    r = sim.run(20000)
    print(f"flusher={'ON ' if use_flusher else 'OFF'}  "
          f"app IOPS={r.app_iops:,.0f}  hit={r.hit_rate * 100:.1f}%  "
          f"flush={r.flush_writes}  demand(blocking)={r.demand_writes}  "
          f"stale discards={r.stale_discards}")
    print(f"             per-SSD utilization: "
          f"{[f'{u:.2f}' for u in r.util]}")

"""Serve a small model with batched requests over the paged KV pool,
demonstrating the paper's machinery end to end: set-associative placement,
GClock clean-first eviction, background pre-cleaning (flusher), preemption
with HIGH-priority resume fetches, stale-flush discard.

  PYTHONPATH=src python examples/serve_paged.py
"""
import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models.transformer import init_params
from repro.serving import ServeEngine

cfg = reduced(get_config("qwen3-8b"))
params = init_params(jax.random.PRNGKey(0), cfg)

# pool deliberately small: 12 pages of 8 tokens -> preemption under load
eng = ServeEngine(cfg, params, max_batch=4, page_size=8, num_sets=4,
                  set_size=3)
rng = np.random.default_rng(0)
rids = []
for i in range(8):
    prompt = [int(x) for x in rng.integers(1, cfg.vocab, int(rng.integers(4, 28)))]
    rids.append(eng.submit(prompt, max_new=16))

eng.run(max_steps=1200)
for rid in rids:
    r = eng.result(rid)
    print(f"req {rid}: {r.state:9s} prompt={len(r.prompt):2d} tokens "
          f"-> {r.out[:8]}{'...' if len(r.out) > 8 else ''}")
print("\npool stats:", eng.stats())
eng.close()

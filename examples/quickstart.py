"""Quickstart: the paper's machinery in 60 seconds.

1. SA-cache + GClock flush scores (the policy layer, pure JAX),
2. the dirty-page flusher filling dual-priority queues,
3. a tiny LM trained with the full stack (sharded step + async checkpoints).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

# --- 1. the policy layer --------------------------------------------------
from repro.core import sa_cache
from repro.kernels.ops import flush_scores as flush_scores_kernel

cache = sa_cache.make_cache(num_sets=4, set_size=12)
for tag in range(40):                       # fill with pages, some dirty
    s = jnp.int32(tag % 4)
    _, _, slot, cache = sa_cache.insert(cache, s, jnp.int32(tag),
                                        jnp.bool_(tag % 3 == 0))
scores = sa_cache.flush_scores(cache)
print("flush scores (JAX twin):\n", np.asarray(scores))
kscores = flush_scores_kernel(cache.hits, cache.clock,
                              cache.tags != sa_cache.EMPTY)
assert (np.asarray(kscores) == np.asarray(scores)).all()
print("Pallas flush_score kernel matches the policy layer\n")

# --- 2. flusher + dual-priority queues -------------------------------------
from repro.core.flusher import DirtyPageFlusher
from repro.core.io_queues import HIGH, LOW, DualQueue, IORequest


class View:                                  # minimal CacheView
    def dirty_count(self, s):
        return int((np.asarray(cache.dirty[s]) &
                    (np.asarray(cache.tags[s]) != -1)).sum())

    def flush_candidates(self, s):
        fs = np.asarray(sa_cache.flush_scores(cache))[s]
        d = np.asarray(cache.dirty[s])
        return sorted(((i, int(cache.tags[s, i]), int(fs[i]))
                       for i in range(12) if d[i]), key=lambda t: -t[2])

    def device_of(self, tag):
        return tag % 2


fl = DirtyPageFlusher(View(), n_devices=2, trigger=2)
for s in range(4):
    fl.note_write(s)
q = DualQueue(max_inflight=32, reserved=7)   # paper: 7 of 32 slots reserved
for fr in fl.make_requests(budget=8):
    q.submit(IORequest(payload=fr, priority=LOW))
q.submit(IORequest(payload="application read", priority=HIGH))
first = q.pop_next()
print("first issued request:", first.payload, "(HIGH overtakes the backlog)\n")

# --- 3. tiny end-to-end training ------------------------------------------
from repro.launch.train import main as train

losses = train(["--arch", "tinyllama-1.1b", "--preset", "smoke",
                "--steps", "20", "--batch", "8", "--seq", "64",
                "--lr", "3e-3"])
print(f"\ntrained 20 steps: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
